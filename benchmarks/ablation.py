"""Paper Fig. 5: PLS alone beats static; PLS + loss-aware prioritization
(full DPQuant) is best."""
from __future__ import annotations

from benchmarks.common import cnn_model, emit, make_run, quick_train


def main(epochs=3):
    model = cnn_model()
    for frac in (0.6, 0.9):
        for mode in ("static", "pls", "dpquant"):
            run = make_run(model, dp=True, quant_fraction=frac, seed=11)
            tr = quick_train(run, epochs, mode=mode)
            emit("fig5_ablation", frac=frac, mode=mode,
                 accuracy=f"{tr.history[-1].accuracy:.4f}",
                 loss=f"{tr.history[-1].loss:.4f}")


if __name__ == "__main__":
    main()
