"""Paper Table 1 / Table 4: baseline (static random) vs DPQuant accuracy at
matched privacy budgets and quantized fractions."""
from __future__ import annotations

import numpy as np

from benchmarks.common import cnn_model, emit, make_run, quick_train


def main(epochs=3):
    model = cnn_model(blocks=(1, 1), classes=10)
    for eps_target, sigma in ((4.0, 1.4), (8.0, 1.0)):
        for frac in (0.5, 0.9):
            base_accs = []
            for seed in range(2):
                run = make_run(model, dp=True, sigma=sigma,
                               quant_fraction=frac, seed=seed)
                tr = quick_train(run, epochs, mode="static")
                base_accs.append(tr.history[-1].accuracy)
            run = make_run(model, dp=True, sigma=sigma,
                           quant_fraction=frac, seed=7)
            ours = quick_train(run, epochs, mode="dpquant")
            emit("table1_accuracy",
                 eps_target=eps_target, frac=frac,
                 baseline_mean=f"{np.mean(base_accs):.4f}",
                 baseline_std=f"{np.std(base_accs):.4f}",
                 dpquant=f"{ours.history[-1].accuracy:.4f}",
                 eps_spent=f"{ours.history[-1].eps:.3f}")


if __name__ == "__main__":
    main()
