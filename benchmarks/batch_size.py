"""Paper Table 2 / A.1: gradient-norm ranges are insensitive to batch size
under DP-SGD (the noise scale is set by C, not B)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import cnn_model, emit, make_run
from repro.data.synthetic import ImageClassDataset
from repro.dp.clip import per_example_clipped_grad_sum
from repro.train_loop import Trainer


def main():
    model = cnn_model()
    ds = ImageClassDataset(n=512, num_classes=8, image_size=16)
    run = make_run(model, dp=True)
    tr = Trainer(run, ds, mode="static")
    tr.train(2)

    def loss_one(p, ex, rng):
        b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
        return tr.model.loss_fn(p, b1, rng,
                                jnp.zeros((model.policy_len(),)))

    for batch_size in (16, 32, 64, 128):
        idx = np.random.RandomState(0).randint(0, 512, batch_size)
        batch = ds.get(idx)
        _, metrics = per_example_clipped_grad_sum(
            loss_one, tr.params, batch, clip_norm=1e9,
            microbatch_size=min(batch_size, 32), rng=jax.random.PRNGKey(0))
        emit("table2_batch_size", batch=batch_size,
             norm_mean=f"{float(metrics['grad_norm_mean']):.4f}",
             norm_max=f"{float(metrics['grad_norm_max']):.4f}")


if __name__ == "__main__":
    main()
