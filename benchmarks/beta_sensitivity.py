"""Paper Table 9 / A.7: temperature beta sweep — moderate-to-high beta
(loss-aware but still stochastic) beats beta -> 0 (pure random)."""
from __future__ import annotations

from benchmarks.common import cnn_model, emit, make_run, quick_train


def main(epochs=3):
    model = cnn_model()
    for beta in (0.1, 1.0, 10.0, 50.0):
        run = make_run(model, dp=True, quant_fraction=0.6, beta=beta,
                       seed=5, analysis_interval=1)
        tr = quick_train(run, epochs, mode="dpquant")
        emit("table9_beta", beta=beta,
             accuracy=f"{tr.history[-1].accuracy:.4f}",
             loss=f"{tr.history[-1].loss:.4f}")


if __name__ == "__main__":
    main()
