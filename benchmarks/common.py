"""Shared benchmark harness utilities.

Every benchmark reproduces one paper table/figure at CPU scale (reduced
models, synthetic data — see DESIGN.md §6) and prints ``name,value,...``
CSV rows so runs are diffable.

Timing protocol (the PR-1/PR-3 lesson): this container throttles the CPU
under sustained load, so phase-ordered timing (all of variant A, then all
of variant B) attributes the slowdown to whichever variant runs last.
Every comparative benchmark therefore *interleaves* its variants —
``interleave_timed`` alternates one invocation of each runner per
repetition so slow machine drift hits all variants equally — and robust
aggregation takes the median repetition (``median_by``).
"""
from __future__ import annotations

import sys
import time
from typing import Any, Callable, Dict, List

from repro.config import (DPConfig, ModelConfig, OptimConfig, QuantConfig,
                          RunConfig)
from repro.data.synthetic import ImageClassDataset
from repro.train_loop import Trainer

_CSV_HEADER_PRINTED = set()


def interleave_timed(fns: Dict[str, Callable[[], Any]],
                     reps: int) -> Dict[str, List[Any]]:
    """Run each named zero-arg runner once per repetition, alternating
    variants to cancel machine drift/throttling.

    The within-rep order reverses on every other repetition (A B, B A,
    A B, ...): with a fixed order, throttling that builds up while the
    first variant runs lands systematically on the second one — the
    palindromic schedule cancels pair-periodic effects as well as slow
    drift.

    Returns ``{name: [result per rep]}``; runners do their own timing and
    return whatever they measure (a wall-clock float, a metrics dict, ...).
    """
    out: Dict[str, List[Any]] = {k: [] for k in fns}
    order = list(fns)
    for rep in range(reps):
        for name in (order if rep % 2 == 0 else reversed(order)):
            out[name].append(fns[name]())
    return out


def median_by(reps: List[Any], key: Callable[[Any], float]):
    """The repetition with the median ``key`` value (odd-length robust)."""
    return sorted(reps, key=key)[len(reps) // 2]


def bench_trainers(trainers: Dict[str, Trainer], *, epochs: int,
                   steps_per_epoch: int, warmup_epochs: int = 1) -> dict:
    """Interleaved epoch timing for a dict of named Trainers.

    All trainers are warmed first (compile + shared data-cache population),
    then epochs alternate across variants via ``interleave_timed``.
    Returns ``{name: {epochs, steps, wall_s, steps_per_sec, ms_per_step}}``.
    """
    for tr in trainers.values():
        for _ in range(warmup_epochs):
            tr.train_epoch(-1)

    def timed_epoch(tr: Trainer) -> Callable[[], float]:
        counter = iter(range(epochs))

        def run() -> float:
            t0 = time.perf_counter()
            tr.train_epoch(next(counter))
            return time.perf_counter() - t0

        return run

    walls = {name: sum(reps) for name, reps in interleave_timed(
        {n: timed_epoch(tr) for n, tr in trainers.items()},
        reps=epochs).items()}
    steps = epochs * steps_per_epoch
    return {name: {"epochs": epochs, "steps": steps, "wall_s": dt,
                   "steps_per_sec": steps / dt,
                   "ms_per_step": dt / steps * 1e3}
            for name, dt in walls.items()}


def emit(table: str, **kv):
    if table not in _CSV_HEADER_PRINTED:
        print(f"# {table}: " + ",".join(kv.keys()))
        _CSV_HEADER_PRINTED.add(table)
    print(table + "," + ",".join(str(v) for v in kv.values()))
    sys.stdout.flush()


def cnn_model(blocks=(1, 1), classes=8, size=16):
    return ModelConfig(name="bench-cnn", family="resnet",
                       resnet_blocks=blocks, num_classes=classes,
                       image_size=size, compute_dtype="float32")


def make_run(model=None, *, fmt="luq_fp4", dp=True, sigma=1.0,
             quant_fraction=0.6, steps_per_epoch=4, batch=32, seed=0,
             optimizer="sgd", lr=0.5, analysis_interval=2, beta=10.0,
             ema_alpha=0.3, analysis_noise=0.5):
    model = model or cnn_model()
    return RunConfig(
        model=model, quant=QuantConfig(fmt=fmt),
        dp=DPConfig(enabled=dp, clip_norm=1.0, noise_multiplier=sigma,
                    microbatch_size=batch, quant_fraction=quant_fraction,
                    analysis_interval=analysis_interval, analysis_reps=1,
                    beta=beta, ema_alpha=ema_alpha,
                    analysis_noise=analysis_noise),
        optim=OptimConfig(name=optimizer, lr=lr),
        global_batch=batch, steps_per_epoch=steps_per_epoch,
        steps=1000, seed=seed)


def quick_train(run, epochs, mode, train_ds=None, eval_ds=None):
    train_ds = train_ds or ImageClassDataset(
        n=512, num_classes=run.model.num_classes,
        image_size=run.model.image_size, noise=0.4, seed=run.seed)
    eval_ds = eval_ds or ImageClassDataset(
        n=192, num_classes=run.model.num_classes,
        image_size=run.model.image_size, noise=0.4, seed=run.seed + 1000)
    tr = Trainer(run, train_ds, eval_dataset=eval_ds, mode=mode)
    tr.train(epochs)
    return tr
