"""Shared benchmark harness utilities.

Every benchmark reproduces one paper table/figure at CPU scale (reduced
models, synthetic data — see DESIGN.md §6) and prints ``name,value,...``
CSV rows so runs are diffable.
"""
from __future__ import annotations

import sys
import time

from repro.config import (DPConfig, ModelConfig, OptimConfig, QuantConfig,
                          RunConfig)
from repro.data.synthetic import ImageClassDataset
from repro.train_loop import Trainer

_CSV_HEADER_PRINTED = set()


def emit(table: str, **kv):
    if table not in _CSV_HEADER_PRINTED:
        print(f"# {table}: " + ",".join(kv.keys()))
        _CSV_HEADER_PRINTED.add(table)
    print(table + "," + ",".join(str(v) for v in kv.values()))
    sys.stdout.flush()


def cnn_model(blocks=(1, 1), classes=8, size=16):
    return ModelConfig(name="bench-cnn", family="resnet",
                       resnet_blocks=blocks, num_classes=classes,
                       image_size=size, compute_dtype="float32")


def make_run(model=None, *, fmt="luq_fp4", dp=True, sigma=1.0,
             quant_fraction=0.6, steps_per_epoch=4, batch=32, seed=0,
             optimizer="sgd", lr=0.5, analysis_interval=2, beta=10.0,
             ema_alpha=0.3, analysis_noise=0.5):
    model = model or cnn_model()
    return RunConfig(
        model=model, quant=QuantConfig(fmt=fmt),
        dp=DPConfig(enabled=dp, clip_norm=1.0, noise_multiplier=sigma,
                    microbatch_size=batch, quant_fraction=quant_fraction,
                    analysis_interval=analysis_interval, analysis_reps=1,
                    beta=beta, ema_alpha=ema_alpha,
                    analysis_noise=analysis_noise),
        optim=OptimConfig(name=optimizer, lr=lr),
        global_batch=batch, steps_per_epoch=steps_per_epoch,
        steps=1000, seed=seed)


def quick_train(run, epochs, mode, train_ds=None, eval_ds=None):
    train_ds = train_ds or ImageClassDataset(
        n=512, num_classes=run.model.num_classes,
        image_size=run.model.image_size, noise=0.4, seed=run.seed)
    eval_ds = eval_ds or ImageClassDataset(
        n=192, num_classes=run.model.num_classes,
        image_size=run.model.image_size, noise=0.4, seed=run.seed + 1000)
    tr = Trainer(run, train_ds, eval_dataset=eval_ds, mode=mode)
    tr.train(epochs)
    return tr
