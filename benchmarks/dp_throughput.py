"""DP gradient-engine benchmark: vmap vs ghost clipped-grad throughput.

Times the full jitted DP-SGD train step (``launch.steps.build_train_setup``
step_fn: clipped grad sum + Gaussian noise + SGD update) for the two
per-example gradient engines (``DPConfig.grad_mode``) on one transformer
and one ResNet config, sweeping the batch size.  Per batch point the two
modes' steps are interleaved (``benchmarks/common.interleave_timed``) and
the median repetition is reported, cancelling machine drift/throttling.

What the sweep shows (committed JSON, docs/ARCHITECTURE.md "DP gradient
modes"):

* steps/sec — ghost overtakes vmap as the batch grows.  The vmap path's
  per-example weight grads are B skinny GEMMs per layer (and, for convs,
  XLA's slow grouped-conv wgrad path) plus an O(B x params)
  materialize/norm/clip-reduce pass; ghost replaces them with per-layer
  Gram norms and ONE reweighted batched backward.  At small batch the
  ghost two-pass overhead (second forward) dominates and vmap wins —
  the crossover is the point of the mode switch.
* per-example gradient state — ``repro.dp.ghost.per_example_state_bytes``:
  vmap materializes ``B x params_total`` floats per microbatch; ghost only
  materializes the non-hooked fallback leaves (norm scales, embeddings,
  heads), so its per-example state is an order of magnitude flatter in B.
  (Gram buffers are O(B x T^2) transients, excluded.)

    PYTHONPATH=src python benchmarks/dp_throughput.py
    PYTHONPATH=src python benchmarks/dp_throughput.py --smoke   # CI job
    # sharded-ghost smoke on a fake 8-device mesh, microbatched pass 1:
    PYTHONPATH=src python benchmarks/dp_throughput.py --smoke \
        --grad-mode ghost --mesh 8x1 --microbatch 1

Writes ``BENCH_dp_throughput.json`` (cwd) and prints ``dp_throughput,...``
CSV rows (see benchmarks/common.py).
"""
from __future__ import annotations

import os
import sys

# --mesh spawns fake host devices, which must be configured BEFORE the
# first jax import anywhere in the process (both "--mesh 8x1" and
# "--mesh=8x1" spellings)
def _peek_mesh_arg(argv):
    for i, tok in enumerate(argv):
        if tok == "--mesh" and i + 1 < len(argv):
            return argv[i + 1]
        if tok.startswith("--mesh="):
            return tok.split("=", 1)[1]
    return None


_mesh_arg = _peek_mesh_arg(sys.argv)
if _mesh_arg:
    _n = 1
    for _part in _mesh_arg.split("x"):
        _n *= int(_part)
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --xla_force_host_platform_device_count"
                               f"={_n}")

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from common import emit, interleave_timed, median_by, make_run
from repro.config import ModelConfig
from repro.dp.ghost import per_example_state_bytes
from repro.launch.mesh import make_compat_mesh, make_host_mesh
from repro.launch.steps import build_train_setup
from repro.models.registry import build_model

ALL_MODES = ("vmap", "ghost")


def lm_model(smoke: bool) -> ModelConfig:
    """Short-sequence LM sized so per-example wgrads are skinny GEMMs and
    B x params materialization is substantial — the regime DP large-batch
    training lives in (the paper's LM setting at CPU scale).  remat off:
    nothing at bench scale needs it, and rematerialization doubles the
    ghost engine's forward recompute (same choice as quant_backends)."""
    if smoke:
        return ModelConfig(name="dp-lm-bench", family="dense_lm",
                           n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                           head_dim=16, d_ff=128, vocab_size=128,
                           compute_dtype="float32", remat=False)
    return ModelConfig(name="dp-lm-bench", family="dense_lm",
                       n_layers=4, d_model=384, n_heads=8, n_kv_heads=8,
                       head_dim=48, d_ff=768, vocab_size=512,
                       compute_dtype="float32", remat=False)


def cnn_model(smoke: bool) -> ModelConfig:
    return ModelConfig(name="dp-cnn-bench", family="resnet",
                       resnet_blocks=(1, 1), num_classes=8,
                       image_size=8 if smoke else 16,
                       compute_dtype="float32")


def make_batch(cfg: ModelConfig, batch: int, seq_len: int):
    if cfg.family == "dense_lm":
        return {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (batch, seq_len), 0, cfg.vocab_size)}
    s = cfg.image_size
    return {"image": jax.random.normal(jax.random.PRNGKey(1),
                                       (batch, s, s, cfg.in_channels)),
            "label": jax.random.randint(jax.random.PRNGKey(2), (batch,),
                                        0, cfg.num_classes)}


def bench_point(cfg: ModelConfig, batch: int, seq_len: int, fmt: str,
                reps: int, modes=ALL_MODES, mesh_shape=None,
                ghost_microbatch: int = 0) -> dict:
    """One (model, batch) sweep point: median-rep step time per mode."""
    mesh = (make_compat_mesh(mesh_shape, ("data", "model")[:len(mesh_shape)]
                             if len(mesh_shape) == 2 else ("data",))
            if mesh_shape else make_host_mesh())
    data = make_batch(cfg, batch, seq_len)
    qflags = jnp.ones((cfg.policy_len(),), jnp.float32)
    steps = {}
    for mode in modes:
        run = make_run(cfg, fmt=fmt, dp=True, batch=batch, optimizer="sgd")
        run = dataclasses.replace(
            run, seq_len=seq_len,
            dp=dataclasses.replace(run.dp, grad_mode=mode,
                                   ghost_microbatch=ghost_microbatch))
        model = build_model(cfg, run.quant)
        setup = build_train_setup(model, run, mesh, batch_size=batch,
                                  seq_len=seq_len)
        params = model.init(jax.random.PRNGKey(0))
        opt_state = setup.opt_init_fn(params)
        fn = jax.jit(setup.step_fn)
        # warm call exists only to compile; the timed reps below re-feed
        # the same initial params/opt_state (no donation on this jit)
        jax.block_until_ready(
            fn(params, opt_state, data, jnp.uint32(0), qflags,
               jnp.float32(0.5)))
        steps[mode] = (fn, params, opt_state)
        last_model, last_params = model, params

    def timed(mode):
        fn, params, opt_state = steps[mode]

        def run_once() -> float:
            t0 = time.perf_counter()
            jax.block_until_ready(
                fn(params, opt_state, data, jnp.uint32(0), qflags,
                   jnp.float32(0.5)))
            return time.perf_counter() - t0

        return run_once

    results = interleave_timed({m: timed(m) for m in modes}, reps=reps)
    point = {"batch": batch}
    for mode in modes:
        wall = median_by(results[mode], lambda t: t)
        point[mode] = {"step_s_median": wall, "steps_per_sec": 1.0 / wall,
                       "step_s_reps": results[mode]}
    if "vmap" in point and "ghost" in point:
        point["speedup_ghost_over_vmap"] = (point["vmap"]["step_s_median"]
                                            / point["ghost"]["step_s_median"])
    # analytic per-example gradient state (the batch-scaling memory term),
    # counted from the params already initialized for the timed steps;
    # with the model's GhostAux hooks (dense_lm) ghost state is exactly 0
    aux = (last_model.ghost_aux(qflags)
           if last_model.ghost_aux is not None else None)
    point["per_example_state_bytes"] = per_example_state_bytes(
        last_params, last_model.ghost_mask(last_params), batch, aux=aux)
    return point


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for the CI smoke job")
    ap.add_argument("--batches", type=int, nargs="*", default=None)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--fmt", default="luq_fp4")
    ap.add_argument("--grad-mode", default="both",
                    choices=["both", "vmap", "ghost"],
                    help="restrict the timed modes (CI smokes the ghost "
                         "path alone on the fake-device mesh)")
    ap.add_argument("--microbatch", type=int, default=0,
                    help="ghost_microbatch pass-1 chunk size for the "
                         "ghost rows (0 = whole batch)")
    ap.add_argument("--mesh", default=None,
                    help="AxB fake-device mesh shape, e.g. 8x1 — spawns "
                         "XLA host devices and exercises the sharded "
                         "ghost driver (must be first-parsed: sets "
                         "XLA_FLAGS before jax import)")
    ap.add_argument("--out", default="BENCH_dp_throughput.json")
    args = ap.parse_args(argv)
    modes = ALL_MODES if args.grad_mode == "both" else (args.grad_mode,)
    mesh_shape = (tuple(int(p) for p in args.mesh.split("x"))
                  if args.mesh else None)

    # odd rep counts keep median_by an actual median (with 2 reps the
    # upper-middle element is the worst run, not a median)
    reps = args.reps or (3 if args.smoke else 5)
    seq_len = 8 if args.smoke else 16

    models = {"transformer": lm_model(args.smoke),
              "resnet": cnn_model(args.smoke)}
    # the vmap->ghost crossover for the LM sits around B ~ 48-64 on this
    # host, so the transformer sweep extends to 128 where the gap is wide
    batches_by_model = {
        "transformer": args.batches or ((2, 4) if args.smoke
                                        else (8, 16, 32, 64, 128)),
        "resnet": args.batches or ((2, 4) if args.smoke
                                   else (8, 16, 32, 64)),
    }
    payload = {
        "benchmark": "dp_throughput",
        "note": ("full jitted DP-SGD step (clip+noise+SGD) per mode; "
                 "interleaved reps, median reported; "
                 "per_example_state_bytes is the analytic batch-scaling "
                 "memory term (vmap: B x all params; ghost: B x non-hooked "
                 "fallback leaves only)"),
        "config": {"fmt": args.fmt,
                   "batches": {k: list(v)
                               for k, v in batches_by_model.items()},
                   "reps": reps, "seq_len": seq_len, "smoke": args.smoke,
                   "modes": list(modes), "mesh": args.mesh,
                   "ghost_microbatch": args.microbatch},
        "models": {},
    }
    for name, cfg in models.items():
        sweep = []
        for batch in batches_by_model[name]:
            point = bench_point(cfg, batch, seq_len, args.fmt, reps,
                                modes=modes, mesh_shape=mesh_shape,
                                ghost_microbatch=args.microbatch)
            sweep.append(point)
            row = {"model": name, "batch": batch}
            for m in modes:
                row[f"{m}_sps"] = round(point[m]["steps_per_sec"], 3)
            if "speedup_ghost_over_vmap" in point:
                row["speedup"] = round(point["speedup_ghost_over_vmap"], 3)
            row["vmap_state_mb"] = round(
                point["per_example_state_bytes"]["vmap_bytes"] / 2**20, 1)
            row["ghost_state_mb"] = round(
                point["per_example_state_bytes"]["ghost_bytes"] / 2**20, 1)
            emit("dp_throughput", **row)
        payload["models"][name] = {
            "model_config": {"family": cfg.family,
                             "d_model": cfg.d_model,
                             "n_layers": cfg.n_layers,
                             "d_ff": cfg.d_ff, "vocab": cfg.vocab_size,
                             "resnet_blocks": list(cfg.resnet_blocks),
                             "image_size": cfg.image_size},
            "sweep": sweep,
        }

    lm_sweep = payload["models"]["transformer"]["sweep"]
    big = [p for p in lm_sweep
           if p["batch"] >= 32 and "speedup_ghost_over_vmap" in p]
    if big:
        payload["transformer_speedup_at_batch_ge_32"] = {
            str(p["batch"]): p["speedup_ghost_over_vmap"] for p in big}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    head = (f" (transformer B>=32 ghost speedup: "
            f"{[round(p['speedup_ghost_over_vmap'], 2) for p in big]})"
            if big else "")
    print(f"wrote {args.out}{head}")


if __name__ == "__main__":
    main()
