"""Paper Table 10 / A.8: EMA smoothing of the noisy sensitivity estimates
stabilizes the ranking vs using the latest (noisy) measurement alone."""
from __future__ import annotations

from benchmarks.common import cnn_model, emit, make_run, quick_train


def main(epochs=3):
    model = cnn_model()
    for alpha, label in ((0.3, "with_ema"), (1.0, "without_ema")):
        run = make_run(model, dp=True, quant_fraction=0.6, ema_alpha=alpha,
                       analysis_interval=1, seed=13)
        tr = quick_train(run, epochs, mode="dpquant")
        emit("table10_ema", variant=label, ema_alpha=alpha,
             accuracy=f"{tr.history[-1].accuracy:.4f}",
             loss=f"{tr.history[-1].loss:.4f}")


if __name__ == "__main__":
    main()
