"""Epoch executor benchmark: looped vs scanned DP-SGD epochs (steps/sec).

Measures the throughput of ``Trainer.train_epoch`` for the two executors on
a synthetic ResNet config (the paper's primary model family at CPU scale).
The looped path dispatches one jitted step at a time and syncs the host on
every step; the scanned path compiles the whole epoch into one
``jax.lax.scan`` program with donated buffers and syncs once per epoch.

The scanned program is the pure-compute baseline, so
``overhead_ms_per_step = wall(loop) - wall(scan)`` isolates the per-step
host cost (dispatch, argument processing, loss sync, accounting) that the
scan executor removes.  On a slow/few-core CPU the DP step is heavily
compute-bound and the wall-clock ratio is modest; on hosts where dispatch
latency rivals step compute (async GPU/TPU backends, many-core CPUs with
small models) the same elimination is the difference between host-bound
and device-bound training.

    PYTHONPATH=src python benchmarks/epoch_executor.py
    PYTHONPATH=src python benchmarks/epoch_executor.py --smoke   # CI job

Writes ``BENCH_epoch_executor.json`` (cwd) and prints ``epoch_executor,...``
CSV rows (see benchmarks/common.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from common import bench_trainers, emit, make_run
from repro.config import ModelConfig
from repro.data.synthetic import ImageClassDataset
from repro.train_loop import Trainer


def bench_executors(base_run, dataset, *, epochs: int,
                    warmup_epochs: int = 2) -> dict:
    """Time both executors via the shared interleaved protocol."""
    trainers = {
        executor: Trainer(dataclasses.replace(base_run,
                                              epoch_executor=executor),
                          dataset, mode="static")
        for executor in ("loop", "scan")}
    results = bench_trainers(trainers, epochs=epochs,
                             steps_per_epoch=base_run.steps_per_epoch,
                             warmup_epochs=warmup_epochs)
    return {executor: {"executor": executor, **r}
            for executor, r in results.items()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for the CI smoke job")
    ap.add_argument("--epochs", type=int, default=None)
    ap.add_argument("--steps-per-epoch", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--unroll", type=int, default=1,
                    help="lax.scan unroll for the scan executor (costly "
                         "compile; >1 only pays off on fast hosts)")
    ap.add_argument("--out", default="BENCH_epoch_executor.json")
    args = ap.parse_args(argv)

    epochs = args.epochs or (2 if args.smoke else 6)
    spe = args.steps_per_epoch or (4 if args.smoke else 32)
    batch = args.batch or (2 if args.smoke else 2)

    # Synthetic ResNet (paper's primary family), sized so the per-step host
    # overhead — the thing the scan executor removes — is visible next to
    # the heavily compute-bound DP per-example-gradient step.
    model = ModelConfig(name="resnet18-bench", family="resnet",
                        resnet_blocks=(1, 1), num_classes=10,
                        image_size=8 if args.smoke else 16,
                        compute_dtype="float32")
    base = dataclasses.replace(
        make_run(model, fmt="luq_fp4", dp=True, batch=batch,
                 steps_per_epoch=spe, optimizer="sgd"),
        epoch_unroll=args.unroll)
    ds = ImageClassDataset(n=512, num_classes=10,
                           image_size=model.image_size, noise=0.4, seed=0)
    # Fully materialize the example cache up front: the executors share the
    # dataset, and whichever runs an epoch first would otherwise pay every
    # generation miss for both (biasing the comparison).
    ds.get(np.arange(ds.n))

    results = bench_executors(base, ds, epochs=epochs)
    for r in results.values():
        emit("epoch_executor", executor=r["executor"], steps=r["steps"],
             wall_s=round(r["wall_s"], 4),
             steps_per_sec=round(r["steps_per_sec"], 3))

    speedup = (results["scan"]["steps_per_sec"]
               / results["loop"]["steps_per_sec"])
    overhead = (results["loop"]["ms_per_step"]
                - results["scan"]["ms_per_step"])
    emit("epoch_executor", executor="speedup", steps="-", wall_s="-",
         steps_per_sec=round(speedup, 3))

    payload = {
        "benchmark": "epoch_executor",
        "config": {"model": "resnet18-bench (blocks=(1,1), synthetic)",
                   "image_size": model.image_size, "batch": batch,
                   "steps_per_epoch": spe, "epochs": epochs, "dp": True,
                   "fmt": "luq_fp4", "unroll": args.unroll,
                   "smoke": args.smoke},
        "loop": results["loop"], "scan": results["scan"],
        "speedup_scan_over_loop": speedup,
        "host_overhead_removed_ms_per_step": overhead,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out} (speedup {speedup:.2f}x, "
          f"host overhead removed {overhead:.2f} ms/step)")


if __name__ == "__main__":
    main()
