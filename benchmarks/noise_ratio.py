"""Paper Fig. 1b/1c: the injected DP noise dominates the clipped gradient
per-coordinate (||n||_inf >> ||g||_inf ~ ||g||_2-driven), and raw gradient
norms under DP-SGD exceed plain SGD's."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import cnn_model, emit, make_run
from repro.data.synthetic import ImageClassDataset
from repro.train_loop import Trainer


def main(epochs=3):
    model = cnn_model()
    ds = ImageClassDataset(n=256, num_classes=8, image_size=16, noise=0.4)

    # Fig 1b: grad/noise elementwise ratio at one step
    run = make_run(model, dp=True)
    tr = Trainer(run, ds, mode="static")
    batch = ds.get(np.arange(32))
    from repro.dp.clip import per_example_clipped_grad_sum

    def loss_one(p, ex, rng):
        b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
        return tr.model.loss_fn(p, b1, rng, jnp.zeros((model.policy_len(),)))

    gsum, _ = per_example_clipped_grad_sum(
        loss_one, tr.params, batch, clip_norm=1.0, microbatch_size=32,
        rng=jax.random.PRNGKey(0))
    g = np.concatenate([np.asarray(l).ravel()
                        for l in jax.tree_util.tree_leaves(gsum)]) / 32
    noise = np.random.RandomState(0).normal(0, 1.0 * 1.0 / 32, g.shape)
    ratio = np.log2(np.abs(noise).mean() / np.abs(g).mean())
    emit("fig1b_noise_ratio", log2_noise_over_grad=f"{ratio:.2f}",
         grad_linf=f"{np.abs(g).max():.3e}",
         noise_linf=f"{np.abs(noise).max():.3e}")

    # Fig 1c: raw grad norms, SGD vs DP-SGD trained params
    for dp in (False, True):
        run = make_run(model, dp=dp, fmt="none",
                       lr=0.5 if dp else 0.05)
        t = Trainer(run, ds, mode="static")
        t.train(epochs)
        gsum2, metrics = per_example_clipped_grad_sum(
            lambda p, ex, rng: t.model.loss_fn(
                p, jax.tree_util.tree_map(lambda x: x[None], ex), rng,
                jnp.zeros((model.policy_len(),))),
            t.params, batch, clip_norm=1e9, microbatch_size=32,
            rng=jax.random.PRNGKey(1))
        emit("fig1c_grad_norms", dp=dp,
             mean_norm=f"{float(metrics['grad_norm_mean']):.4f}",
             max_norm=f"{float(metrics['grad_norm_max']):.4f}")


if __name__ == "__main__":
    main()
