"""Paper Tables 11-12 / A.9: FP8 shows little DP degradation (scheduling
matters less); uniform INT4 is harsher than LUQ-FP4."""
from __future__ import annotations

from benchmarks.common import cnn_model, emit, make_run, quick_train


def main(epochs=3):
    model = cnn_model()
    for fmt in ("none", "fp8_e5m2", "luq_fp4", "int4"):
        for mode in ("static", "dpquant"):
            run = make_run(model, dp=True, quant_fraction=0.9, fmt=fmt,
                           seed=21)
            tr = quick_train(run, epochs, mode=mode)
            emit("table11_12_quantizers", fmt=fmt, mode=mode,
                 accuracy=f"{tr.history[-1].accuracy:.4f}",
                 loss=f"{tr.history[-1].loss:.4f}")


if __name__ == "__main__":
    main()
