"""Paper Fig. 4: random quantized-layer subsets trace an accuracy spread;
DPQuant's schedule lands near the top (Pareto front) at each budget."""
from __future__ import annotations

import numpy as np

from benchmarks.common import cnn_model, emit, make_run, quick_train


def main(epochs=3, n_random=4):
    model = cnn_model()
    for frac in (0.4, 0.8):
        accs = []
        for seed in range(n_random):
            run = make_run(model, dp=True, quant_fraction=frac, seed=seed)
            tr = quick_train(run, epochs, mode="static")
            accs.append(tr.history[-1].accuracy)
            emit("fig4_pareto", budget=frac, policy=f"random{seed}",
                 accuracy=f"{accs[-1]:.4f}")
        run = make_run(model, dp=True, quant_fraction=frac, seed=123)
        tr = quick_train(run, epochs, mode="dpquant")
        ours = tr.history[-1].accuracy
        emit("fig4_pareto", budget=frac, policy="dpquant",
             accuracy=f"{ours:.4f}")
        emit("fig4_pareto_summary", budget=frac,
             random_mean=f"{np.mean(accs):.4f}",
             random_best=f"{np.max(accs):.4f}",
             dpquant=f"{ours:.4f}")


if __name__ == "__main__":
    main()
