"""Paper Fig. 3: cumulative privacy of training + analysis; the analysis
fraction is small at realistic train-steps:analysis ratios and shrinks as
training proceeds."""
from __future__ import annotations

from repro.dp.accountant import RDPAccountant
from benchmarks.common import emit


def main():
    # paper-like setting: batch 1024 over |D|=26640 (GTSRB), sigma=1.0,
    # analysis every 2 epochs at sigma_measure=0.5
    q = 1024 / 26_640
    q_analysis = 32 / 26_640        # n_sample probe batches (Table 3)
    steps_per_epoch = 26
    acc = RDPAccountant()
    train_only = RDPAccountant()
    for epoch in range(1, 61):
        for a in (acc, train_only):
            a.step(noise_multiplier=1.0, sample_rate=q,
                   steps=steps_per_epoch, label="train")
        if epoch % 2 == 0:
            acc.step(noise_multiplier=0.5, sample_rate=q_analysis, steps=1,
                     label="analysis")
        if epoch % 10 == 0:
            eps, _ = acc.get_epsilon(1e-5)
            eps_t, _ = train_only.get_epsilon(1e-5)
            frac = acc.analysis_fraction(1e-5)
            emit("fig3_privacy_cost", epoch=epoch,
                 eps_total=f"{eps:.3f}", eps_train_only=f"{eps_t:.3f}",
                 marginal_analysis_eps=f"{eps - eps_t:.4f}",
                 analysis_rdp_fraction=f"{frac:.4f}")


if __name__ == "__main__":
    main()
