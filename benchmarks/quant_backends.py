"""Quantizer-backend benchmark: ref (jnp) vs pallas (fused kernels) DP steps.

Times ``Trainer.train_epoch`` for the two quantizer backends
(``QuantConfig.backend``) on one ResNet and one transformer config — the
two families the serve/train hot paths quantize — re-using the interleaved
drift-cancelling protocol of ``benchmarks/epoch_executor.py``: both
backends' trainers are warmed (compile) first, then epochs alternate
ref/pallas so slow machine drift hits both equally.

On CPU the pallas kernels run in *interpret mode* (Pallas emulates the TPU
grid with XLA ops), so these numbers measure dispatch correctness and
interpret overhead, not kernel speed — on real TPUs the fused kernels are
the production path and REPRO_PALLAS_INTERPRET=0 compiles them.  The JSON
keeps both readings honest: ``pallas_over_ref_step_ratio`` > 1 on CPU is
expected.

    PYTHONPATH=src python benchmarks/quant_backends.py
    PYTHONPATH=src python benchmarks/quant_backends.py --smoke   # CI job

Writes ``BENCH_quant_backends.json`` (cwd) and prints
``quant_backends,...`` CSV rows (see benchmarks/common.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np

from common import bench_trainers, emit, make_run
from repro.config import ModelConfig
from repro.data.synthetic import ImageClassDataset, TokenDataset
from repro.train_loop import Trainer

BACKENDS = ("ref", "pallas")


def bench_backends(base_run, dataset, *, epochs: int,
                   warmup_epochs: int = 1) -> dict:
    """Time both backends via the shared interleaved protocol."""
    trainers = {
        backend: Trainer(dataclasses.replace(
            base_run, quant=dataclasses.replace(base_run.quant,
                                                backend=backend)),
            dataset, mode="static")
        for backend in BACKENDS}
    results = bench_trainers(trainers, epochs=epochs,
                             steps_per_epoch=base_run.steps_per_epoch,
                             warmup_epochs=warmup_epochs)
    return {backend: {"backend": backend, **r}
            for backend, r in results.items()}


def lm_model() -> ModelConfig:
    return ModelConfig(name="lm-bench", family="dense_lm",
                       n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                       head_dim=16, d_ff=128, vocab_size=256,
                       compute_dtype="float32", remat=False)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for the CI smoke job")
    ap.add_argument("--epochs", type=int, default=None)
    ap.add_argument("--steps-per-epoch", type=int, default=None)
    ap.add_argument("--out", default="BENCH_quant_backends.json")
    args = ap.parse_args(argv)

    epochs = args.epochs or (1 if args.smoke else 3)
    spe = args.steps_per_epoch or (2 if args.smoke else 8)
    batch = 2

    configs = {
        "resnet": {
            "model": ModelConfig(name="resnet-bench", family="resnet",
                                 resnet_blocks=(1,), num_classes=8,
                                 image_size=8 if args.smoke else 16,
                                 compute_dtype="float32"),
            "seq_len": None,
        },
        "transformer": {
            "model": lm_model(),
            "seq_len": 16 if args.smoke else 32,
        },
    }

    env_override = os.environ.get("REPRO_QUANT_BACKEND")
    if env_override:
        # resolve_backend lets the env var beat QuantConfig.backend, so
        # both legs would silently run the same backend
        print(f"warning: REPRO_QUANT_BACKEND={env_override!r} is set and "
              "overrides both legs; unset it for a real ref-vs-pallas "
              "comparison (the JSON records the override)")

    payload = {"benchmark": "quant_backends",
               "env_backend_override": env_override,
               "note": ("pallas runs in Pallas interpret mode on CPU "
                        "(grid emulated with XLA ops); ratios > 1 vs ref "
                        "are expected off-TPU"),
               "config": {"epochs": epochs, "steps_per_epoch": spe,
                          "batch": batch, "fmt": "luq_fp4", "dp": True,
                          "smoke": args.smoke},
               "models": {}}

    for name, cfg in configs.items():
        run = make_run(cfg["model"], fmt="luq_fp4", dp=True, batch=batch,
                       steps_per_epoch=spe, optimizer="sgd",
                       quant_fraction=1.0)
        if cfg["seq_len"]:
            run = dataclasses.replace(run, seq_len=cfg["seq_len"])
        if cfg["model"].family == "resnet":
            ds = ImageClassDataset(n=128, num_classes=8,
                                   image_size=cfg["model"].image_size,
                                   noise=0.4, seed=0)
        else:
            ds = TokenDataset(n=128, vocab=cfg["model"].vocab_size,
                              seq_len=cfg["seq_len"], seed=0)
        # materialize the shared example cache up front (both backends
        # read the same dataset; see benchmarks/epoch_executor.py)
        ds.get(np.arange(ds.n))

        results = bench_backends(run, ds, epochs=epochs)
        ratio = (results["pallas"]["ms_per_step"]
                 / results["ref"]["ms_per_step"])
        for r in results.values():
            emit("quant_backends", model=name, backend=r["backend"],
                 steps=r["steps"], wall_s=round(r["wall_s"], 4),
                 ms_per_step=round(r["ms_per_step"], 3))
        emit("quant_backends", model=name, backend="pallas/ref",
             steps="-", wall_s="-", ms_per_step=round(ratio, 3))
        payload["models"][name] = {
            "ref": results["ref"], "pallas": results["pallas"],
            "pallas_over_ref_step_ratio": ratio,
        }

    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
