"""Paper Fig. 1a / Table 8: accuracy degradation from FP4 quantization is
far worse under DP-SGD than under plain SGD, and grows with the number of
quantized layers."""
from __future__ import annotations

import time

from benchmarks.common import cnn_model, emit, make_run, quick_train


def main(epochs=3):
    model = cnn_model()
    n_layers = model.policy_len()
    for dp in (False, True):
        for frac in (0.0, 0.5, 1.0):
            t0 = time.time()
            run = make_run(model, dp=dp, quant_fraction=frac,
                           fmt="luq_fp4" if frac > 0 else "none",
                           lr=0.5 if dp else 0.05)
            tr = quick_train(run, epochs, mode="static")
            acc = tr.history[-1].accuracy
            emit("fig1a_degradation",
                 dp=dp, frac_quantized=frac,
                 accuracy=f"{acc:.4f}",
                 loss=f"{tr.history[-1].loss:.4f}",
                 us_per_call=f"{(time.time()-t0)*1e6/epochs:.0f}")


if __name__ == "__main__":
    main()
