"""§Roofline report: aggregates results/dryrun/*.json into the per-cell
three-term table (EXPERIMENTS.md §Roofline is generated from this)."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit


def main(results_dir="results/dryrun"):
    rd = Path(results_dir)
    if not rd.exists():
        print("# no dry-run results found; run "
              "`python -m repro.launch.dryrun` first")
        return
    for path in sorted(rd.glob("*.json")):
        rec = json.loads(path.read_text())
        if rec.get("status") == "skipped":
            emit("roofline", cell=path.stem, status="SKIP",
                 compute_s="", memory_s="", collective_s="",
                 dominant="", useful_ratio="")
            continue
        if rec.get("status") != "ok":
            emit("roofline", cell=path.stem, status="ERROR",
                 compute_s="", memory_s="", collective_s="",
                 dominant="", useful_ratio="")
            continue
        r = rec["roofline"]
        emit("roofline", cell=path.stem, status="ok",
             compute_s=f"{r['compute_s']:.3e}",
             memory_s=f"{r['memory_s']:.3e}",
             collective_s=f"{r['collective_s']:.3e}",
             dominant=r["dominant"],
             useful_ratio=(f"{r['useful_ratio']:.3f}"
                           if r.get("useful_ratio") else ""))


if __name__ == "__main__":
    main()
