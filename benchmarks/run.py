"""Benchmark harness — one module per paper table/figure (DESIGN.md §6).

    PYTHONPATH=src python -m benchmarks.run [--only fig3,fig6] [--fast]

Prints ``table,key=value,...`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = [
    ("fig1a_table8", "benchmarks.quant_degradation"),
    ("fig1bc", "benchmarks.noise_ratio"),
    ("fig3", "benchmarks.privacy_cost"),
    ("fig4", "benchmarks.pareto"),
    ("table1", "benchmarks.accuracy_table"),
    ("fig5", "benchmarks.ablation"),
    ("fig6_table14", "benchmarks.speedup"),
    ("table2", "benchmarks.batch_size"),
    ("table9", "benchmarks.beta_sensitivity"),
    ("table10", "benchmarks.ema_ablation"),
    ("table11_12", "benchmarks.other_quantizers"),
    ("roofline", "benchmarks.roofline_report"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    failures = 0
    for name, module in SUITES:
        if only and name not in only:
            continue
        print(f"\n### {name} ({module})", flush=True)
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["main"])
            mod.main()
            print(f"### {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"### {name} FAILED: {e}")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
