"""Serving throughput: continuous-batching engine vs the oneshot driver.

A synthetic Poisson arrival trace of mixed prompt/generation lengths is
served twice:

* **oneshot** (the baseline the repo shipped with): fixed batches of
  ``--slots`` requests in arrival order — each group's prompts are padded
  to the group max, its decode lockstepped to the group max generation
  length, and group *i+1* cannot start until group *i* fully drains (a
  fixed batch cannot admit mid-flight).  Total decode ticks =
  sum over groups of max(gen in group).
* **continuous** (``repro.serve.ContinuousEngine``): same device footprint
  (``--slots`` cache rows), but requests are admitted into free slots as
  they arrive, short requests retire and their slots are refilled.  Total
  decode ticks ~ sum(gen) / slots.

Decode on every real serving substrate (and on this CPU — measured in the
committed JSON) is weight-bound: a tick costs roughly the same whether 1
or all slots are active.  Throughput is therefore proportional to slot
*utilization*, which is exactly what lockstep groups waste on mixed
lengths and continuous refill preserves.  Reported ``tokens_per_sec``
counts useful (requested) tokens over the full arrival-to-drain wall;
``speedup_compute_only`` excludes arrival gaps.  p50/p99 latency and TTFT
come from per-request metrics (docs/SERVING.md).

    PYTHONPATH=src python benchmarks/serve_throughput.py          # full trace
    PYTHONPATH=src python benchmarks/serve_throughput.py --smoke  # CI job

Writes ``BENCH_serve_throughput.json`` (cwd) and prints
``serve_throughput,...`` CSV rows (see benchmarks/common.py).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from common import emit, interleave_timed, median_by
from repro.config import (DPConfig, ModelConfig, OptimConfig, QuantConfig,
                          RunConfig, ServeConfig)
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model
from repro.serve import ContinuousEngine, build_oneshot_fns, oneshot_generate


def lm_model(smoke: bool) -> ModelConfig:
    """Bench model: big enough that a decode tick is weight-bound (full),
    tiny for the CI smoke job."""
    if smoke:
        return ModelConfig(name="serve-bench", family="dense_lm",
                           n_layers=2, d_model=32, n_heads=4, n_kv_heads=4,
                           head_dim=8, d_ff=64, vocab_size=256,
                           compute_dtype="float32", remat=False)
    return ModelConfig(name="serve-bench", family="dense_lm",
                       n_layers=4, d_model=256, n_heads=8, n_kv_heads=8,
                       head_dim=32, d_ff=512, vocab_size=4096,
                       compute_dtype="float32", remat=False)


def make_trace(n: int, seed: int, *, max_prompt: int, gens, rate_hz: float):
    """Poisson arrivals with uniform prompt lengths and mixed gen lengths."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    arrivals -= arrivals[0]                      # first request at t=0
    trace = []
    for i in range(n):
        pl = int(rng.integers(4, max_prompt + 1))
        gen = int(rng.choice(gens))
        prompt = rng.integers(0, 256, size=pl).astype(np.int32)
        trace.append({"prompt": prompt, "gen": gen,
                      "arrival": float(arrivals[i])})
    return trace


def prepare_oneshot(model, params, run, trace, *, slots: int):
    """Compile + warm the lockstep group plans (one per ``slots`` chunk).

    Jitted (prefill, decode) pairs are cached by (batch, cache_len)
    geometry so groups that happen to share a shape do not recompile.
    """
    mesh = make_host_mesh()
    groups = [trace[i:i + slots] for i in range(0, len(trace), slots)]
    fns, warmed = {}, set()
    plans = []
    for g in groups:
        max_prompt = max(t["prompt"].size for t in g)
        max_gen = max(t["gen"] for t in g)
        padded = np.zeros((len(g), max_prompt), np.int32)
        for i, t in enumerate(g):
            padded[i, :t["prompt"].size] = t["prompt"]
        geom = (len(g), max_prompt + max_gen)
        if geom not in fns:
            fns[geom] = build_oneshot_fns(model, run, mesh, len(g),
                                          max_prompt + max_gen)
        prefill, decode = fns[geom]
        batch = {"tokens": jnp.asarray(padded)}
        if (geom, max_prompt, max_gen) not in warmed:
            oneshot_generate(prefill, decode, params, batch, max_gen)
            warmed.add((geom, max_prompt, max_gen))
        plans.append((g, prefill, decode, batch, max_gen))
    return plans


def measure_oneshot(plans, params, trace) -> dict:
    """One timed pass: sequential lockstep groups in arrival order.

    Each group pads to its own max prompt/gen; a group starts at
    max(previous group drained, last member arrived).  This is the oneshot
    driver's semantics scaled to a trace: same cache footprint as the
    engine, no mid-flight admission.
    """
    compute_wall = 0.0
    clock = 0.0                      # simulated timeline incl. arrivals
    latencies, ticks = [], 0
    for g, prefill, decode, batch, max_gen in plans:
        t0 = time.perf_counter()
        oneshot_generate(prefill, decode, params, batch, max_gen)
        dt = time.perf_counter() - t0
        compute_wall += dt
        ticks += max_gen
        start = max(clock, max(t["arrival"] for t in g))
        clock = start + dt
        latencies += [clock - t["arrival"] for t in g]
    useful = sum(t["gen"] for t in trace)
    decoded_slots = sum(len(g) * mg for g, _, _, _, mg in plans)
    return {
        "engine": "oneshot", "n_groups": len(plans),
        "decode_ticks": ticks,
        "decoded_token_slots": decoded_slots,
        "useful_new_tokens": useful,
        "compute_wall_s": compute_wall, "wall_s": clock,
        "tokens_per_sec": useful / clock,
        "tokens_per_sec_compute_only": useful / compute_wall,
        "latency_p50_s": float(np.percentile(latencies, 50)),
        "latency_p99_s": float(np.percentile(latencies, 99)),
    }


def prepare_continuous(model, params, trace, *, slots: int, max_seq: int):
    """Build the engine and warm every prompt-length prefill + decode."""
    engine = ContinuousEngine(model, params,
                              ServeConfig(max_slots=slots, max_seq=max_seq))
    for t in trace:
        engine.submit(t["prompt"], max_new_tokens=t["gen"])
    engine.run()
    return engine


def measure_continuous(engine, trace) -> dict:
    """One timed pass of the slot-pool engine (arrival-gated admission)."""
    engine.reset()
    for t in trace:
        engine.submit(t["prompt"], max_new_tokens=t["gen"],
                      arrival_time=t["arrival"])
    t0 = time.perf_counter()
    engine.run()
    wall = time.perf_counter() - t0
    s = engine.metrics.summary()
    return {
        "engine": "continuous", "slots": engine.serve.max_slots,
        "max_seq": engine.serve.max_seq,
        "useful_new_tokens": s["total_new_tokens"],
        "decode_ticks": s["decode_ticks"], "wall_s": wall,
        "idle_wall_s": s["idle_wall_s"],
        "tokens_per_sec": s["total_new_tokens"] / wall,
        # compute-only mirrors the oneshot metric: arrival-wait sleeps
        # (tracked by the engine as idle_wall) are excluded
        "tokens_per_sec_compute_only":
            s["total_new_tokens"] / max(wall - s["idle_wall_s"], 1e-9),
        "latency_p50_s": s["latency_p50_s"],
        "latency_p99_s": s["latency_p99_s"],
        "ttft_p50_s": s["ttft_p50_s"], "ttft_p99_s": s["ttft_p99_s"],
        "queue_wait_p50_s": s["queue_wait_p50_s"],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for the CI smoke job")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--slots", type=int, default=None)
    ap.add_argument("--rate", type=float, default=None,
                    help="Poisson arrival rate (requests/sec)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve_throughput.json")
    args = ap.parse_args(argv)

    n = args.requests or (6 if args.smoke else 16)
    slots = args.slots or (2 if args.smoke else 4)
    # arrival rate is set so the trace saturates the slot pool (offered
    # load above the engine's service rate); at low rates both engines are
    # arrival-limited and the comparison degenerates to idle waiting
    rate = args.rate or 40.0
    gens = (4, 6, 12) if args.smoke else (4, 6, 8, 12, 16, 24, 32, 48)
    max_prompt = 8 if args.smoke else 16

    cfg = lm_model(args.smoke)
    model = build_model(cfg, QuantConfig(fmt="none"))
    params = model.init(jax.random.PRNGKey(args.seed))
    run = RunConfig(model=cfg, quant=QuantConfig(fmt="none"),
                    dp=DPConfig(enabled=False), optim=OptimConfig())
    trace = make_trace(n, args.seed, max_prompt=max_prompt, gens=gens,
                       rate_hz=rate)
    max_seq = max_prompt + max(gens)

    # interleave the timed passes (continuous/oneshot alternating) and take
    # medians (benchmarks/common.py protocol): this container throttles CPU
    # under sustained load, so phase-ordered timing would attribute the
    # slowdown to whichever engine runs last
    plans = prepare_oneshot(model, params, run, trace, slots=slots)
    engine = prepare_continuous(model, params, trace, slots=slots,
                                max_seq=max_seq)
    reps = 3
    results = interleave_timed(
        {"continuous": lambda: measure_continuous(engine, trace),
         "oneshot": lambda: measure_oneshot(plans, params, trace)},
        reps=reps)
    continuous, oneshot = (
        median_by(results["continuous"], lambda r: r["tokens_per_sec"]),
        median_by(results["oneshot"], lambda r: r["tokens_per_sec"]))
    speedup = continuous["tokens_per_sec"] / oneshot["tokens_per_sec"]
    speedup_compute = (continuous["tokens_per_sec_compute_only"]
                       / oneshot["tokens_per_sec_compute_only"])

    for r in (oneshot, continuous):
        emit("serve_throughput", engine=r["engine"],
             tok_s=round(r["tokens_per_sec"], 2),
             p50_ms=round(r["latency_p50_s"] * 1e3, 1),
             p99_ms=round(r["latency_p99_s"] * 1e3, 1))
    emit("serve_throughput", engine="continuous/oneshot",
         tok_s=round(speedup, 3), p50_ms="-", p99_ms="-")

    payload = {
        "benchmark": "serve_throughput",
        "note": ("useful tokens only; oneshot = sequential lockstep groups "
                 "of `slots` requests, padded to group max prompt/gen, no "
                 "mid-flight admission; timed passes interleave the two "
                 "engines and report the median rep to cancel machine "
                 "drift/throttling; speedup_compute_only removes arrival "
                 "waits from BOTH engines (engine idle sleeps / oneshot "
                 "start gating)"),
        "config": {"requests": n, "slots": slots, "rate_hz": rate,
                   "gens": list(gens), "max_prompt": max_prompt,
                   "max_seq": max_seq, "smoke": args.smoke,
                   "seed": args.seed, "reps": reps,
                   "model": {"d_model": cfg.d_model,
                             "n_layers": cfg.n_layers,
                             "vocab": cfg.vocab_size}},
        "trace": [{"prompt_len": t["prompt"].size, "gen": t["gen"],
                   "arrival_s": round(t["arrival"], 4)} for t in trace],
        "oneshot": oneshot,
        "continuous": continuous,
        "speedup_tokens_per_sec": speedup,
        "speedup_compute_only": speedup_compute,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out} (speedup {speedup:.2f}x, "
          f"compute-only {speedup_compute:.2f}x)")


if __name__ == "__main__":
    main()
