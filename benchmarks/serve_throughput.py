"""Serving throughput: continuous-batching engine vs the oneshot driver.

A synthetic Poisson arrival trace of mixed prompt/generation lengths is
served twice:

* **oneshot** (the baseline the repo shipped with): fixed batches of
  ``--slots`` requests in arrival order — each group's prompts are padded
  to the group max, its decode lockstepped to the group max generation
  length, and group *i+1* cannot start until group *i* fully drains (a
  fixed batch cannot admit mid-flight).  Total decode ticks =
  sum over groups of max(gen in group).
* **continuous** (``repro.serve.ContinuousEngine``): same device footprint
  (``--slots`` cache rows), but requests are admitted into free slots as
  they arrive, short requests retire and their slots are refilled.  Total
  decode ticks ~ sum(gen) / slots.

Decode on every real serving substrate (and on this CPU — measured in the
committed JSON) is weight-bound: a tick costs roughly the same whether 1
or all slots are active.  Throughput is therefore proportional to slot
*utilization*, which is exactly what lockstep groups waste on mixed
lengths and continuous refill preserves.  Reported ``tokens_per_sec``
counts useful (requested) tokens over the full arrival-to-drain wall;
``speedup_compute_only`` excludes arrival gaps.  p50/p99 latency and TTFT
come from per-request metrics (docs/SERVING.md), with the raw per-request
rows embedded in the JSON.

``--kv-fmt`` sweeps KV-cache storage formats: for each format the engine
is measured on the same trace, its cache bytes/slot are reported against
the fp32 (``none``) pool, and every request's tokens are checked against
the B=1 oneshot driver at the same format (quantization is deterministic,
so agreement is exact, not approximate).  The engine's compiled prefill
program count is asserted against the power-of-two bucketing bound
``ceil(log2(max_seq))``.

    PYTHONPATH=src python benchmarks/serve_throughput.py          # full trace
    PYTHONPATH=src python benchmarks/serve_throughput.py --smoke  # CI job
    PYTHONPATH=src python benchmarks/serve_throughput.py \
        --smoke --kv-fmt int8                                     # CI kv job

Writes ``BENCH_serve_throughput.json`` (cwd) and prints
``serve_throughput,...`` CSV rows (see benchmarks/common.py).
"""
from __future__ import annotations

import argparse
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from common import emit, interleave_timed, median_by
from repro.config import (DPConfig, ModelConfig, OptimConfig, QuantConfig,
                          RunConfig, ServeConfig)
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model
from repro.serve import ContinuousEngine, build_oneshot_fns, oneshot_generate


def lm_model(smoke: bool) -> ModelConfig:
    """Bench model: big enough that a decode tick is weight-bound (full),
    tiny for the CI smoke job."""
    if smoke:
        return ModelConfig(name="serve-bench", family="dense_lm",
                           n_layers=2, d_model=32, n_heads=4, n_kv_heads=4,
                           head_dim=8, d_ff=64, vocab_size=256,
                           compute_dtype="float32", remat=False)
    return ModelConfig(name="serve-bench", family="dense_lm",
                       n_layers=4, d_model=256, n_heads=8, n_kv_heads=8,
                       head_dim=32, d_ff=512, vocab_size=4096,
                       compute_dtype="float32", remat=False)


def make_trace(n: int, seed: int, *, max_prompt: int, gens, rate_hz: float):
    """Poisson arrivals with uniform prompt lengths and mixed gen lengths."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    arrivals -= arrivals[0]                      # first request at t=0
    trace = []
    for i in range(n):
        pl = int(rng.integers(4, max_prompt + 1))
        gen = int(rng.choice(gens))
        prompt = rng.integers(0, 256, size=pl).astype(np.int32)
        trace.append({"prompt": prompt, "gen": gen,
                      "arrival": float(arrivals[i])})
    return trace


def prepare_oneshot(model, params, run, trace, *, slots: int):
    """Compile + warm the lockstep group plans (one per ``slots`` chunk).

    Jitted (prefill, decode) pairs are cached by (batch, cache_len)
    geometry so groups that happen to share a shape do not recompile.
    """
    mesh = make_host_mesh()
    groups = [trace[i:i + slots] for i in range(0, len(trace), slots)]
    fns, warmed = {}, set()
    plans = []
    for g in groups:
        max_prompt = max(t["prompt"].size for t in g)
        max_gen = max(t["gen"] for t in g)
        padded = np.zeros((len(g), max_prompt), np.int32)
        for i, t in enumerate(g):
            padded[i, :t["prompt"].size] = t["prompt"]
        geom = (len(g), max_prompt + max_gen)
        if geom not in fns:
            fns[geom] = build_oneshot_fns(model, run, mesh, len(g),
                                          max_prompt + max_gen)
        prefill, decode = fns[geom]
        batch = {"tokens": jnp.asarray(padded)}
        if (geom, max_prompt, max_gen) not in warmed:
            oneshot_generate(prefill, decode, params, batch, max_gen)
            warmed.add((geom, max_prompt, max_gen))
        plans.append((g, prefill, decode, batch, max_gen))
    return plans


def measure_oneshot(plans, params, trace) -> dict:
    """One timed pass: sequential lockstep groups in arrival order.

    Each group pads to its own max prompt/gen; a group starts at
    max(previous group drained, last member arrived).  This is the oneshot
    driver's semantics scaled to a trace: same cache footprint as the
    engine, no mid-flight admission.  Per-request TTFT is the group's
    prefill completion minus the request's arrival (every member of a
    lockstep group gets its first token when the group's batched prefill
    finishes).
    """
    compute_wall = 0.0
    clock = 0.0                      # simulated timeline incl. arrivals
    latencies, ttfts, ticks = [], [], 0
    for g, prefill, decode, batch, max_gen in plans:
        t0 = time.perf_counter()
        _, tim = oneshot_generate(prefill, decode, params, batch, max_gen)
        dt = time.perf_counter() - t0
        compute_wall += dt
        ticks += max_gen
        start = max(clock, max(t["arrival"] for t in g))
        clock = start + dt
        latencies += [clock - t["arrival"] for t in g]
        ttfts += [start + tim["prefill_s"] - t["arrival"] for t in g]
    useful = sum(t["gen"] for t in trace)
    decoded_slots = sum(len(g) * mg for g, _, _, _, mg in plans)
    return {
        "engine": "oneshot", "n_groups": len(plans),
        "decode_ticks": ticks,
        "decoded_token_slots": decoded_slots,
        "useful_new_tokens": useful,
        "compute_wall_s": compute_wall, "wall_s": clock,
        "tokens_per_sec": useful / clock,
        "tokens_per_sec_compute_only": useful / compute_wall,
        "latency_p50_s": float(np.percentile(latencies, 50)),
        "latency_p99_s": float(np.percentile(latencies, 99)),
        "ttft_p50_s": float(np.percentile(ttfts, 50)),
        "ttft_p99_s": float(np.percentile(ttfts, 99)),
    }


def prepare_continuous(model, params, trace, *, slots: int, max_seq: int,
                       kv_fmt: str = "none"):
    """Build the engine and warm every prefill bucket + the decode step."""
    engine = ContinuousEngine(
        model, params,
        ServeConfig(max_slots=slots, max_seq=max_seq, kv_fmt=kv_fmt))
    for t in trace:
        engine.submit(t["prompt"], max_new_tokens=t["gen"])
    engine.run()
    return engine


def measure_continuous(engine, trace) -> dict:
    """One timed pass of the slot-pool engine (arrival-gated admission)."""
    engine.reset()
    for t in trace:
        engine.submit(t["prompt"], max_new_tokens=t["gen"],
                      arrival_time=t["arrival"])
    t0 = time.perf_counter()
    engine.run()
    wall = time.perf_counter() - t0
    s = engine.metrics.summary()
    return {
        "engine": "continuous", "slots": engine.serve.max_slots,
        "max_seq": engine.serve.max_seq,
        "kv_fmt": engine.serve.kv_fmt,
        "useful_new_tokens": s["total_new_tokens"],
        "decode_ticks": s["decode_ticks"], "wall_s": wall,
        "idle_wall_s": s["idle_wall_s"],
        "tokens_per_sec": s["total_new_tokens"] / wall,
        # compute-only mirrors the oneshot metric: arrival-wait sleeps
        # (tracked by the engine as idle_wall) are excluded
        "tokens_per_sec_compute_only":
            s["total_new_tokens"] / max(wall - s["idle_wall_s"], 1e-9),
        "latency_p50_s": s["latency_p50_s"],
        "latency_p99_s": s["latency_p99_s"],
        "ttft_p50_s": s["ttft_p50_s"], "ttft_p99_s": s["ttft_p99_s"],
        "queue_wait_p50_s": s["queue_wait_p50_s"],
        "prefill_programs": engine.prefill_programs,
        # fault-tolerance counters (docs/SERVING.md "Failure model &
        # recovery") — all zero on a fault-free bench run, but surfaced so
        # chaos runs and SLO dashboards read from the same JSON
        "shed": s["shed"], "retried": s["retried"],
        "deadline_missed": s["deadline_missed"],
        "recovered": s["recovered"],
        "faults_injected": s["faults_injected"],
        "degraded_events": s["degraded_events"],
        "n_rejected": s["n_rejected"],
        "per_request": engine.metrics.per_request(),
    }


def cache_bytes_per_slot(model, slots: int, max_seq: int,
                         kv_fmt: str) -> float:
    """KV-pool bytes per slot from the slot cache spec (pos excluded)."""
    kw = {} if kv_fmt == "none" else {"kv_fmt": kv_fmt}
    spec = model.slot_cache_spec(slots, max_seq, **kw)
    total = sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
                for name, s in spec.items() if name != "pos")
    return total / slots


def check_agreement(engine, model, params, run, trace) -> bool:
    """Engine tokens == B=1 oneshot tokens at the same kv_fmt, per request.

    Deterministic row quantization makes this exact: both paths quantize
    the same K/V rows with the same bf16 scales, so greedy decoding at a
    matching format must agree token-for-token.
    """
    engine.reset()
    for t in trace:
        engine.submit(t["prompt"], max_new_tokens=t["gen"])
    results = engine.run()
    mesh = make_host_mesh()
    fns = {}
    for rid, t in enumerate(trace):
        cache_len = t["prompt"].size + t["gen"]
        if cache_len not in fns:
            fns[cache_len] = build_oneshot_fns(model, run, mesh, 1, cache_len,
                                               kv_fmt=engine.serve.kv_fmt)
        prefill, decode = fns[cache_len]
        ref, _ = oneshot_generate(prefill, decode, params,
                                  {"tokens": jnp.asarray(t["prompt"])[None]},
                                  t["gen"])
        if results[rid].tokens.tolist() != ref[0].tolist():
            return False
    return True


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for the CI smoke job")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--slots", type=int, default=None)
    ap.add_argument("--rate", type=float, default=None,
                    help="Poisson arrival rate (requests/sec)")
    ap.add_argument("--kv-fmt", default=None,
                    help="comma-separated KV-cache storage formats to sweep "
                         "(default: none,int8,luq_fp4; smoke: none)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve_throughput.json")
    args = ap.parse_args(argv)

    n = args.requests or (6 if args.smoke else 16)
    slots = args.slots or (2 if args.smoke else 4)
    # arrival rate is set so the trace saturates the slot pool (offered
    # load above the engine's service rate); at low rates both engines are
    # arrival-limited and the comparison degenerates to idle waiting
    rate = args.rate or 40.0
    gens = (4, 6, 12) if args.smoke else (4, 6, 8, 12, 16, 24, 32, 48)
    max_prompt = 8 if args.smoke else 16
    kv_fmts = [s.strip() for s in
               (args.kv_fmt or ("none" if args.smoke
                                else "none,int8,luq_fp4")).split(",")
               if s.strip()]

    cfg = lm_model(args.smoke)
    model = build_model(cfg, QuantConfig(fmt="none"))
    params = model.init(jax.random.PRNGKey(args.seed))
    run = RunConfig(model=cfg, quant=QuantConfig(fmt="none"),
                    dp=DPConfig(enabled=False), optim=OptimConfig())
    trace = make_trace(n, args.seed, max_prompt=max_prompt, gens=gens,
                       rate_hz=rate)
    max_seq = max_prompt + max(gens)
    prefill_bound = math.ceil(math.log2(max_seq))

    # interleave the timed passes (per-format continuous + oneshot,
    # alternating) and take medians (benchmarks/common.py protocol): this
    # container throttles CPU under sustained load, so phase-ordered timing
    # would attribute the slowdown to whichever engine runs last
    plans = prepare_oneshot(model, params, run, trace, slots=slots)
    engines = {fmt: prepare_continuous(model, params, trace, slots=slots,
                                       max_seq=max_seq, kv_fmt=fmt)
               for fmt in kv_fmts}
    reps = 3
    timed = {"oneshot": lambda: measure_oneshot(plans, params, trace)}
    for fmt in kv_fmts:
        timed[f"continuous[{fmt}]"] = (
            lambda e=engines[fmt]: measure_continuous(e, trace))
    results = interleave_timed(timed, reps=reps)
    oneshot = median_by(results["oneshot"], lambda r: r["tokens_per_sec"])
    by_fmt = {fmt: median_by(results[f"continuous[{fmt}]"],
                             lambda r: r["tokens_per_sec"])
              for fmt in kv_fmts}

    # primary comparison (headline speedup) stays the fp32 cache when the
    # sweep includes it, so the committed numbers are comparable across PRs
    primary = "none" if "none" in by_fmt else kv_fmts[0]
    continuous = by_fmt[primary]
    speedup = continuous["tokens_per_sec"] / oneshot["tokens_per_sec"]
    speedup_compute = (continuous["tokens_per_sec_compute_only"]
                       / oneshot["tokens_per_sec_compute_only"])

    base_bytes = cache_bytes_per_slot(model, slots, max_seq, "none")
    sweep = {}
    for fmt in kv_fmts:
        bps = cache_bytes_per_slot(model, slots, max_seq, fmt)
        agree = check_agreement(engines[fmt], model, params, run, trace)
        r = by_fmt[fmt]
        assert r["prefill_programs"] <= prefill_bound, (
            f"{r['prefill_programs']} prefill programs exceeds the "
            f"bucketing bound ceil(log2({max_seq})) = {prefill_bound}")
        sweep[fmt] = dict(
            r, cache_bytes_per_slot=bps,
            bytes_reduction_vs_none=base_bytes / bps,
            tokens_match_oneshot=agree)
        emit("serve_throughput", engine=f"continuous[{fmt}]",
             tok_s=round(r["tokens_per_sec"], 2),
             p50_ms=round(r["latency_p50_s"] * 1e3, 1),
             p99_ms=round(r["latency_p99_s"] * 1e3, 1))
        if not agree:
            raise SystemExit(
                f"kv_fmt={fmt}: engine tokens diverge from the oneshot "
                "reference — deterministic quantization contract broken")

    emit("serve_throughput", engine="oneshot",
         tok_s=round(oneshot["tokens_per_sec"], 2),
         p50_ms=round(oneshot["latency_p50_s"] * 1e3, 1),
         p99_ms=round(oneshot["latency_p99_s"] * 1e3, 1))
    emit("serve_throughput", engine="continuous/oneshot",
         tok_s=round(speedup, 3), p50_ms="-", p99_ms="-")

    payload = {
        "benchmark": "serve_throughput",
        "note": ("useful tokens only; oneshot = sequential lockstep groups "
                 "of `slots` requests, padded to group max prompt/gen, no "
                 "mid-flight admission; timed passes interleave the "
                 "engines and report the median rep to cancel machine "
                 "drift/throttling; speedup_compute_only removes arrival "
                 "waits from BOTH engines (engine idle sleeps / oneshot "
                 "start gating); kv_fmt_sweep reports per-format cache "
                 "bytes/slot vs the fp32 pool and exact engine-vs-oneshot "
                 "token agreement (deterministic quantization)"),
        "config": {"requests": n, "slots": slots, "rate_hz": rate,
                   "gens": list(gens), "max_prompt": max_prompt,
                   "max_seq": max_seq, "smoke": args.smoke,
                   "seed": args.seed, "reps": reps,
                   "kv_fmts": kv_fmts,
                   "model": {"d_model": cfg.d_model,
                             "n_layers": cfg.n_layers,
                             "vocab": cfg.vocab_size}},
        "trace": [{"prompt_len": t["prompt"].size, "gen": t["gen"],
                   "arrival_s": round(t["arrival"], 4)} for t in trace],
        "oneshot": oneshot,
        "continuous": continuous,
        "kv_fmt_sweep": sweep,
        "prefill_program_bound": prefill_bound,
        "speedup_tokens_per_sec": speedup,
        "speedup_compute_only": speedup_compute,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out} (speedup {speedup:.2f}x, "
          f"compute-only {speedup_compute:.2f}x; kv bytes/slot reduction: "
          + ", ".join(f"{f}={sweep[f]['bytes_reduction_vs_none']:.2f}x"
                      for f in kv_fmts) + ")")


if __name__ == "__main__":
    main()
