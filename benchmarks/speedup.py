"""Paper Fig. 6 / Table 14: theoretical speedup with the linear cost model

    T_ours = T_analysis + (1 - p + p/4) (T_train - T_overhead) + T_overhead

using the paper's measured overhead fractions (Table 14) and a measured
T_analysis/T_train ratio from our trainer."""
from __future__ import annotations

import time

from benchmarks.common import cnn_model, emit, make_run
from repro.data.synthetic import ImageClassDataset
from repro.train_loop import Trainer

# paper Table 14 overhead percentages
OVERHEAD = {
    "resnet18_gtsrb": 0.0599,
    "resnet50_gtsrb": 0.0710,
    "densenet121_gtsrb": 0.0623,
    "densenet121_cifar10": 0.0455,
    "resnet18_emnist": 0.1981,
}


def main():
    # measure the analysis:train time ratio on the reduced model
    model = cnn_model()
    run = make_run(model, dp=True, quant_fraction=0.9, analysis_interval=1)
    ds = ImageClassDataset(n=256, num_classes=8, image_size=16)
    tr = Trainer(run, ds, mode="dpquant")
    t0 = time.time()
    tr.train_epoch(0)          # includes one analysis
    t_with = time.time() - t0
    t0 = time.time()
    tr.scheduler.mode_saved = tr.mode
    tr.mode = "static"
    tr.train_epoch(1)          # no analysis
    t_without = time.time() - t0
    analysis_frac = max(0.0, (t_with - t_without) / max(t_without, 1e-9))
    emit("fig6_measured", analysis_time_fraction=f"{analysis_frac:.3f}")

    p = 0.9                    # 90% of layers quantized (paper Fig. 6)
    speedup_fp4 = 4.0
    for name, oh in OVERHEAD.items():
        t_train = 1.0
        t_overhead = oh * t_train
        t_analysis = min(analysis_frac, 0.05) * t_train
        t_ours = (t_analysis
                  + (1 - p + p / speedup_fp4) * (t_train - t_overhead)
                  + t_overhead)
        emit("fig6_speedup", config=name,
             overhead_pct=f"{oh*100:.2f}",
             speedup=f"{t_train / t_ours:.2f}x")


if __name__ == "__main__":
    main()
