"""Paper-style experiment: ResNet18 on a CIFAR-like synthetic dataset,
comparing DPQuant against the static-random-subset baseline at 90% layers
quantized (paper Table 1 setting, reduced scale for CPU).

    PYTHONPATH=src python examples/dp_cifar_resnet.py [--epochs 8]
"""
import argparse
import sys
sys.path.insert(0, "src")

from repro.config import (DPConfig, ModelConfig, OptimConfig, QuantConfig,
                          RunConfig)
from repro.data.synthetic import ImageClassDataset
from repro.train_loop import Trainer


def run_mode(mode: str, epochs: int, seed: int = 0):
    model = ModelConfig(name="resnet18-cifar", family="resnet",
                        resnet_blocks=(2, 2, 2, 2), num_classes=10,
                        image_size=24, compute_dtype="float32")
    run = RunConfig(
        model=model,
        quant=QuantConfig(fmt="luq_fp4"),
        dp=DPConfig(enabled=True, clip_norm=1.0, noise_multiplier=1.0,
                    microbatch_size=16, quant_fraction=0.9,
                    analysis_interval=2, analysis_reps=2, beta=10.0),
        optim=OptimConfig(name="sgd", lr=0.5),
        global_batch=64, steps_per_epoch=8, steps=epochs * 8, seed=seed)
    train_ds = ImageClassDataset(n=2048, num_classes=10, image_size=24,
                                 noise=0.5, seed=seed)
    eval_ds = ImageClassDataset(n=512, num_classes=10, image_size=24,
                                noise=0.5, seed=seed + 100)
    tr = Trainer(run, train_ds, eval_dataset=eval_ds, mode=mode)
    tr.train(epochs, verbose=True)
    return tr.history[-1]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=6)
    args = ap.parse_args()
    print("=== static random baseline (90% quantized) ===")
    base = run_mode("static", args.epochs)
    print("\n=== DPQuant (PLS + loss-aware prioritization) ===")
    ours = run_mode("dpquant", args.epochs)
    print(f"\nbaseline: acc={base.accuracy:.1%} eps={base.eps:.2f}")
    print(f"dpquant : acc={ours.accuracy:.1%} eps={ours.eps:.2f}")


if __name__ == "__main__":
    main()
