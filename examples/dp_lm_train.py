"""End-to-end driver: DP-Adam training of a ~100M-parameter GQA transformer
LM with DPQuant dynamic FP4 scheduling on synthetic token data.

Default arguments are CPU-sized; the full 100M/300-step run is

    PYTHONPATH=src python examples/dp_lm_train.py \
        --d-model 768 --layers 12 --steps-per-epoch 30 --epochs 10 \
        --batch 8 --seq-len 256

(~100M params with the 32k vocab).  The same code path drives the
production configs through repro.launch.train on a TPU mesh.
"""
import argparse
import sys
sys.path.insert(0, "src")

import jax

from repro.config import (DPConfig, ModelConfig, OptimConfig, QuantConfig,
                          RunConfig)
from repro.data.synthetic import TokenDataset
from repro.train_loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--heads", type=int, default=8)
    ap.add_argument("--kv-heads", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=32_000)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--steps-per-epoch", type=int, default=5)
    ap.add_argument("--quant-fraction", type=float, default=0.75)
    args = ap.parse_args()

    model = ModelConfig(
        name="lm-100m", family="dense_lm", n_layers=args.layers,
        d_model=args.d_model, n_heads=args.heads, n_kv_heads=args.kv_heads,
        head_dim=args.d_model // args.heads, d_ff=4 * args.d_model,
        vocab_size=args.vocab, mlp_activation="swiglu",
        compute_dtype="float32", attn_chunk_q=64,
        ce_chunk=64, pad_vocab_to=128)
    n_params = (args.vocab * args.d_model
                + args.layers * (4 * args.d_model ** 2 // 1
                                 + 12 * args.d_model ** 2))
    print(f"~{n_params/1e6:.0f}M parameters "
          f"({jax.local_device_count()} devices)")

    run = RunConfig(
        model=model,
        quant=QuantConfig(fmt="luq_fp4"),
        dp=DPConfig(enabled=True, clip_norm=0.5, noise_multiplier=0.8,
                    microbatch_size=max(1, args.batch // 2),
                    quant_fraction=args.quant_fraction,
                    analysis_interval=2, analysis_reps=1, beta=10.0),
        optim=OptimConfig(name="adam", lr=3e-4),     # DP-Adam (paper A.5)
        global_batch=args.batch, seq_len=args.seq_len,
        steps_per_epoch=args.steps_per_epoch,
        steps=args.epochs * args.steps_per_epoch, seed=0)

    ds = TokenDataset(n=2048, vocab=args.vocab, seq_len=args.seq_len)
    tr = Trainer(run, ds, mode="dpquant")
    tr.train(args.epochs, verbose=True)
    print("\nper-layer EMA loss-impact scores (higher = keep full precision):")
    for i, s in enumerate(tr.scheduler.scores):
        print(f"  layer {i}: {s:+.5f}")


if __name__ == "__main__":
    main()
