"""Quickstart: DPQuant in ~40 lines.

Trains a small ResNet with DP-SGD where 60% of layers run in (simulated)
LUQ-FP4 each epoch, with the quantized subset chosen by DPQuant's
loss-aware scheduler.  Prints per-epoch loss / epsilon / quantized layers.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

from repro.config import (DPConfig, ModelConfig, OptimConfig, QuantConfig,
                          RunConfig)
from repro.data.synthetic import ImageClassDataset
from repro.train_loop import Trainer


def main():
    model = ModelConfig(name="quickstart-cnn", family="resnet",
                        resnet_blocks=(1, 1), num_classes=10,
                        image_size=16, compute_dtype="float32")
    run = RunConfig(
        model=model,
        quant=QuantConfig(fmt="luq_fp4"),          # paper's LUQ-FP4 format
        dp=DPConfig(enabled=True, clip_norm=1.0, noise_multiplier=1.0,
                    microbatch_size=16, quant_fraction=0.6,
                    analysis_interval=2, analysis_reps=2, beta=10.0),
        optim=OptimConfig(name="sgd", lr=0.5),      # paper Table 5
        global_batch=32, steps_per_epoch=8, steps=80, seed=0)

    train_ds = ImageClassDataset(n=1024, num_classes=10, image_size=16)
    eval_ds = ImageClassDataset(n=256, num_classes=10, image_size=16, seed=7)

    trainer = Trainer(run, train_ds, eval_dataset=eval_ds, mode="dpquant")
    trainer.train(6, verbose=True)
    final = trainer.history[-1]
    print(f"\nDone. eps spent = {final.eps:.2f} "
          f"(analysis fraction {final.analysis_eps_fraction:.1%}), "
          f"final accuracy = {final.accuracy:.1%}")


if __name__ == "__main__":
    main()
