"""Serve a small LM with batched prefill + KV-cache decode, with the logits
head routed through the quantizer-backend dispatcher's fused LUQ matmul
(``repro.quant.backend``, backend="pallas" — interpret mode on CPU).

    PYTHONPATH=src python examples/serve_quantized.py
"""
import sys
sys.path.insert(0, "src")

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "gemma-7b", "--smoke",
                "--batch", "2", "--prompt-len", "16", "--gen", "8",
                "--quant-fmt", "luq_fp4", "--backend", "pallas"]
    main()
