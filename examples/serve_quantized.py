"""Quantized continuous-batching serving demo.

Serves a small LM with the slot-pool engine (``repro.serve``): requests are
admitted into free slots, decoded in one fused masked step per tick, and
the logits head routes through the quantizer-backend dispatcher's fused
LUQ matmul (``repro.quant.backend``, backend="pallas" — interpret mode on
CPU).  Compare with ``--engine oneshot`` to see the lockstep reference.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import sys
sys.path.insert(0, "src")

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "gemma-7b", "--smoke",
                "--engine", "continuous", "--slots", "2", "--requests", "4",
                "--prompt-len", "16", "--gen", "8",
                "--quant-fmt", "luq_fp4", "--backend", "pallas"]
    main()
