"""CI chaos smoke: serve + train under a fixed-seed FaultPlan.

Two legs, both driven by explicit seeded fault schedules (5 distinct
fault kinds across the run):

1. **serve** — a tiny dense LM through the continuous engine + supervisor
   with prefill/decode dispatch failures, slot-cache poison, a frozen
   clock, and a replica death injected; every request must recover to
   status "ok" with tokens bit-identical to a fault-free run, and the
   recovery counters must show the faults actually fired.
2. **train** — a 2-epoch DP run preempted mid-epoch by an injected
   "preempt" fault, resumed from the mid-epoch checkpoint in a fresh
   trainer; the resumed run must end bit-identical (params + epsilon) to
   an uninterrupted run.

The fired-fault log plus the recovery counters land in
``chaos_fault_log.json`` (``--out``), which CI uploads as an artifact.

    PYTHONPATH=src python scripts/chaos_smoke.py [--out chaos_fault_log.json]
"""
import argparse
import json
import sys
import tempfile

import jax
import numpy as np


def serve_leg() -> dict:
    from repro.config import ModelConfig, QuantConfig, ServeConfig
    from repro.models.registry import build_model
    from repro.runtime.faults import FaultEvent, FaultPlan
    from repro.runtime.supervisor import ServeSupervisor, run_supervised
    from repro.serve import ContinuousEngine

    cfg = ModelConfig(name="lm-chaos", family="dense_lm", n_layers=2,
                      d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                      d_ff=64, vocab_size=64, compute_dtype="float32",
                      remat=False)
    model = build_model(cfg, QuantConfig(fmt="none"))
    params = model.init(jax.random.PRNGKey(0))
    serve = ServeConfig(max_slots=2, max_seq=16, temperature=1.0, seed=3,
                        max_retries=5)
    specs = [(5, 8), (3, 6), (7, 8), (4, 7)]

    def submit_all(engine):
        for i, (pl, g) in enumerate(specs):
            prompt = np.asarray(jax.random.randint(
                jax.random.PRNGKey(40 + i), (pl,), 0, cfg.vocab_size),
                np.int32)
            engine.submit(prompt, max_new_tokens=g)

    ref_engine = ContinuousEngine(model, params, serve)
    submit_all(ref_engine)
    ref = {rid: r.tokens.tolist() for rid, r in ref_engine.run().items()}

    plan = FaultPlan([
        FaultEvent(kind="prefill_fail", at=1),
        FaultEvent(kind="decode_fail", at=2),
        FaultEvent(kind="replica_death", at=3, target=1),
        FaultEvent(kind="clock_freeze", at=4, duration=6),
        FaultEvent(kind="slot_corrupt", at=5, target=1),
    ], seed=11)
    engine = ContinuousEngine(model, params, serve, faults=plan)
    sup = ServeSupervisor(engine, n_replicas=3, faults=plan,
                          slot_fault_threshold=10)
    submit_all(engine)
    out = run_supervised(engine)

    assert plan.pending == [], f"unfired faults: {plan.pending}"
    for rid, toks in ref.items():
        assert out[rid].status == "ok", (rid, out[rid].status)
        assert out[rid].tokens.tolist() == toks, \
            f"request {rid} diverged from the fault-free run"
    s = engine.metrics.summary()
    assert s["faults_injected"] == 5, s["faults_injected"]
    assert s["retried"] >= 1 and s["recovered"] >= 1
    assert s["degraded_events"] >= 1 and sup.dead == {1}
    print(f"serve leg: {len(ref)} requests token-identical under "
          f"{s['faults_injected']} injected faults "
          f"({s['retried']} retries, {s['recovered']} recovered, "
          f"{s['degraded_events']} degraded events)")
    return {"plan": json.loads(plan.log_json()), "summary": s,
            "supervisor_events": sup.events}


def train_leg() -> dict:
    from repro.config import (DPConfig, ModelConfig, OptimConfig,
                              QuantConfig, RunConfig)
    from repro.data.synthetic import ImageClassDataset
    from repro.runtime.faults import FaultEvent, FaultPlan
    from repro.runtime.preemption import Preempted, PreemptionHandler
    from repro.train_loop import Trainer

    cfg = ModelConfig(name="cnn-chaos", family="resnet",
                      resnet_blocks=(1, 1), num_classes=8, image_size=16,
                      compute_dtype="float32")
    run = RunConfig(
        model=cfg, quant=QuantConfig(fmt="luq_fp4"),
        dp=DPConfig(enabled=True, clip_norm=1.0, noise_multiplier=1.0,
                    microbatch_size=16, quant_fraction=0.6,
                    analysis_interval=2, analysis_reps=1),
        optim=OptimConfig(name="sgd", lr=0.5),
        global_batch=16, steps_per_epoch=4, steps=100, seed=0,
        epoch_executor="scan", epoch_chunk=2)

    def ds():
        return ImageClassDataset(n=256, num_classes=8, image_size=16,
                                 noise=0.4)

    ref = Trainer(run, ds(), mode="dpquant")
    ref.train(2)

    preempt_at = 6                       # mid-epoch 1 (chunk boundary)
    plan = FaultPlan([FaultEvent(kind="preempt", at=preempt_at)], seed=0)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        tr1 = Trainer(run, ds(), mode="dpquant", checkpoint_dir=ckpt_dir,
                      preemption=PreemptionHandler(faults=plan))
        try:
            tr1.train(2)
            raise AssertionError("injected preemption never fired")
        except Preempted as p:
            assert p.step == preempt_at, p.step
        tr2 = Trainer(run, ds(), mode="dpquant", checkpoint_dir=ckpt_dir)
        assert tr2.restore_latest() is not None
        assert tr2._mid_epoch is not None
        tr2.train(2 - tr2._next_epoch)
        tr2.ckpt.wait()

    for a, b in zip(jax.tree_util.tree_leaves(ref.params),
                    jax.tree_util.tree_leaves(tr2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    eps_ref = ref.accountant.get_epsilon(1e-5)
    eps_res = tr2.accountant.get_epsilon(1e-5)
    assert eps_ref == eps_res, (eps_ref, eps_res)
    print(f"train leg: preempt@step {preempt_at} + resume is bit-identical "
          f"(eps={eps_res[0]:.3f}, {tr2.step} steps)")
    return {"plan": json.loads(plan.log_json()),
            "preempt_step": preempt_at,
            "final_eps": float(eps_res[0]),
            "final_loss": float(tr2.history[-1].loss)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="chaos_fault_log.json")
    args = ap.parse_args(argv)
    log = {"serve": serve_leg(), "train": train_leg()}
    with open(args.out, "w") as f:
        json.dump(log, f, indent=2)
    print(f"chaos smoke passed; fault log written to {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
