"""Markdown link checker for README.md and docs/ (no external deps).

Validates every inline markdown link/image in the given files/directories:

* relative paths must exist on disk (resolved from the linking file),
* ``#anchors`` — bare or on a relative ``.md`` target — must match a
  heading in the target file (GitHub-style slugification),
* ``http(s)://`` / ``mailto:`` links are skipped (CI has no network).

Usage (CI docs job and tests/test_docs.py):

    python scripts/check_docs_links.py README.md docs

Exits 1 and prints one line per broken link otherwise.
"""
from __future__ import annotations

import functools
import re
import sys
from pathlib import Path

# inline links/images: [text](target) — stops at the first unescaped ')'
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$")
_CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces->hyphens."""
    text = re.sub(r"`([^`]*)`", r"\1", heading)          # strip code spans
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # strip links
    text = text.strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


@functools.lru_cache(maxsize=None)
def md_anchors(path: Path) -> frozenset:
    """All heading anchors of a markdown file (outside code fences)."""
    anchors, counts = set(), {}
    in_fence = False
    for line in path.read_text().splitlines():
        if _CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = _HEADING_RE.match(line)
        if m:
            slug = github_slug(m.group(1))
            n = counts.get(slug, 0)
            counts[slug] = n + 1
            anchors.add(slug if n == 0 else f"{slug}-{n}")
    return frozenset(anchors)


def iter_links(path: Path):
    """Yield link targets of a markdown file (outside code fences)."""
    in_fence = False
    for line in path.read_text().splitlines():
        if _CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in _LINK_RE.finditer(line):
            yield m.group(1)


def check_file(path: Path) -> list:
    """Return a list of broken-link descriptions for one markdown file."""
    errors = []
    for target in iter_links(path):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("#"):
            if target[1:] not in md_anchors(path):
                errors.append(f"{path}: broken anchor {target!r}")
            continue
        rel, _, anchor = target.partition("#")
        dest = (path.parent / rel).resolve()
        if not dest.exists():
            errors.append(f"{path}: missing target {target!r}")
            continue
        if anchor:
            if dest.suffix != ".md":
                errors.append(f"{path}: anchor on non-markdown {target!r}")
            elif anchor not in md_anchors(dest):
                errors.append(f"{path}: broken anchor {target!r}")
    return errors


def collect(args) -> list:
    """Expand CLI args (files or directories) into markdown files."""
    files = []
    for a in args:
        p = Path(a)
        if p.is_dir():
            files += sorted(p.rglob("*.md"))
        else:
            files.append(p)
    return files


def main(argv=None) -> int:
    """Check every file/dir given on the command line; 0 = all links ok."""
    args = argv if argv is not None else sys.argv[1:]
    if not args:
        args = ["README.md", "docs"]
    errors = []
    files = collect(args)
    for f in files:
        errors += check_file(f)
    for e in errors:
        print(e)
    print(f"checked {len(files)} markdown files: "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
