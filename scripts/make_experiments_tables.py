"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from
results/dryrun/*.json (run after the sweep + hillclimb complete)."""
from __future__ import annotations

import json
import sys
from pathlib import Path

HW = "197 TFLOP/s bf16 | 819 GB/s HBM | 50 GB/s/link ICI"


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if b < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def load(results_dir):
    import sys as _sys
    _sys.path.insert(0, "src")
    recs = []
    for p in sorted(Path(results_dir).glob("*.json")):
        r = json.loads(p.read_text())
        r["_file"] = p.stem
        if r.get("status") == "ok":
            _recompute_ratio(r)
        recs.append(r)
    return recs


def _recompute_ratio(r):
    """Re-derive useful_ratio with the attention-aware MODEL_FLOPS (some
    cells were recorded before the attention term was added)."""
    try:
        import jax
        from repro.configs import get_config
        from repro.launch import roofline as rl
        from repro.models.registry import build_model
        from repro.config import QuantConfig, SHAPES
        cfg = get_config(r["arch"])
        shape = SHAPES[r["shape"]]
        model = build_model(cfg, QuantConfig())
        ap = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        mf = rl.model_flops(cfg, ap, shape.kind, shape.global_batch,
                            shape.seq_len, r["n_devices"])
        r["roofline"]["model_flops_per_device"] = mf
        r["roofline"]["useful_ratio"] = (
            mf / r["roofline"]["flops"] if r["roofline"]["flops"] else None)
    except Exception:
        pass


def dryrun_table(recs):
    lines = ["| cell | mesh | status | args/dev | temp/dev | compile s | HLO flops/dev | coll bytes/dev |",
             "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("tag"):
            continue
        cell = f"{r['arch']} × {r['shape']}"
        if r["status"] == "skipped":
            lines.append(f"| {cell} | {r['mesh']} | SKIP(full-attn) | - | - | - | - | - |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {cell} | {r['mesh']} | ERROR | - | - | - | - | - |")
            continue
        m = r["memory"]
        rf = r["roofline"]
        lines.append(
            f"| {cell} | {r['mesh']} | ok | {fmt_bytes(m['argument_size_in_bytes'])} "
            f"| {fmt_bytes(m['temp_size_in_bytes'])} | {r.get('compile_s','')} "
            f"| {rf['flops']:.2e} | {fmt_bytes(rf['collective_bytes'])} |")
    return "\n".join(lines)


def roofline_table(recs):
    lines = ["| cell | mesh | compute s | memory s | collective s | dominant | useful ratio |",
             "|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("tag") or r["status"] != "ok":
            continue
        if r["mesh"] != "16x16":
            continue          # roofline table is single-pod per assignment
        rf = r["roofline"]
        ratio = f"{rf['useful_ratio']:.3f}" if rf.get("useful_ratio") else "-"
        lines.append(
            f"| {r['arch']} × {r['shape']} | {r['mesh']} | {rf['compute_s']:.3e} "
            f"| {rf['memory_s']:.3e} | {rf['collective_s']:.3e} "
            f"| **{rf['dominant']}** | {ratio} |")
    return "\n".join(lines)


def perf_table(recs):
    by_key = {}
    for r in recs:
        if r["status"] != "ok" or r["mesh"] != "16x16":
            continue
        key = (r["arch"], r["shape"])
        by_key.setdefault(key, {})[r.get("tag") or "baseline"] = r
    lines = []
    for (arch, shape), variants in sorted(by_key.items()):
        if len(variants) < 2:
            continue
        lines.append(f"\n#### {arch} × {shape}\n")
        lines.append("| variant | compute s | memory s | collective s | dominant | Δdominant vs baseline |")
        lines.append("|---|---|---|---|---|---|")
        base = variants.get("baseline")
        bdom = base["roofline"]["dominant"] if base else None
        bval = base["roofline"][f"{bdom}_s"] if base else None
        order = ["baseline"] + sorted(v for v in variants if v != "baseline")
        for tag in order:
            r = variants[tag]
            rf = r["roofline"]
            delta = ""
            if base and bval:
                delta = f"{(1 - rf[f'{bdom}_s'] / bval) * 100:+.1f}%"
            lines.append(
                f"| {tag} | {rf['compute_s']:.3e} | {rf['memory_s']:.3e} "
                f"| {rf['collective_s']:.3e} | {rf['dominant']} | {delta} |")
    return "\n".join(lines)


if __name__ == "__main__":
    results = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(results)
    print("## Dry-run table\n")
    print(dryrun_table(recs))
    print("\n## Roofline table (single-pod)\n")
    print(roofline_table(recs))
    print("\n## Perf variants\n")
    print(perf_table(recs))
