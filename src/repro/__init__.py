"""DPQuant-JAX: differentially-private training with dynamic quantization
scheduling (Gao et al., 2025), as a production JAX framework."""

__version__ = "1.0.0"
