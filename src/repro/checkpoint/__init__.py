from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.serialization import restore, save

__all__ = ["CheckpointManager", "save", "restore"]
