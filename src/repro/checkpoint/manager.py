"""CheckpointManager: retention, async writes, latest-valid discovery.

DP-specific requirement: the RDP accountant history and the DPQuant
scheduler state are part of every checkpoint — a restart that forgot spent
epsilon would silently break the privacy guarantee, and one that forgot the
EMA scores would restart the analysis from scratch (paying extra analysis
budget).  Both are plain dicts and ride in the ``aux`` payload.
"""
from __future__ import annotations

import pickle
import re
import shutil
import threading
from pathlib import Path
from typing import Any, Optional, Tuple

from repro.checkpoint import serialization


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._pending: Optional[threading.Thread] = None
        # a writer killed mid-save leaves only a step_*.tmp staging dir
        # (the .ckpt destination appears atomically via os.replace); sweep
        # such orphans so they never accumulate across restarts
        for stale in self.dir.glob("step_*.tmp"):
            shutil.rmtree(stale, ignore_errors=True)

    # ------------------------------------------------------------------ #
    def _path(self, step: int) -> Path:
        return self.dir / f"step_{step:010d}.ckpt"

    def steps(self):
        out = []
        for p in self.dir.glob("step_*.ckpt"):
            m = re.fullmatch(r"step_(\d+)\.ckpt", p.name)
            if m and (p / "meta.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree: Any, aux: Optional[dict] = None) -> None:
        self.wait()
        # pickle non-jsonable aux bits (e.g. numpy RandomState tuples)
        aux = aux or {}
        blob = {"step": step}
        payload = {"pickled_aux": _pickle_hex(aux), **blob}

        def work():
            serialization.save(self._path(step), tree, payload)
            self._gc()

        if self.async_write:
            self._pending = threading.Thread(target=work, daemon=True)
            self._pending.start()
        else:
            work()

    def _gc(self):
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._path(s), ignore_errors=True)

    # ------------------------------------------------------------------ #
    def restore_latest(self, like: Any, shardings: Any = None
                       ) -> Optional[Tuple[int, Any, dict]]:
        """Latest checkpoint that passes CRC; corrupted ones are skipped."""
        self.wait()
        for step in reversed(self.steps()):
            try:
                tree, aux = serialization.restore(self._path(step), like,
                                                  shardings)
                real_aux = _unpickle_hex(aux.get("pickled_aux", ""))
                return step, tree, real_aux
            except Exception:  # noqa: BLE001 - corrupted checkpoint
                continue
        return None


def _pickle_hex(obj) -> str:
    return pickle.dumps(obj).hex()


def _unpickle_hex(s: str):
    if not s:
        return {}
    return pickle.loads(bytes.fromhex(s))
