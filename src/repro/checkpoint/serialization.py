"""Pytree <-> on-disk serialization (npz + JSON treedef), CRC-checked.

No orbax offline; this is a self-contained format:

  <dir>/step_<N>.ckpt/
    arrays.npz        flat arrays keyed by index
    meta.json         treedef repr, leaf paths, aux state (accountant,
                      scheduler, data cursor), crc32 of arrays.npz

Writes are atomic: serialize into ``<name>.tmp`` then ``os.replace``.
Restore validates the CRC and returns (pytree, aux) — corrupted/partial
checkpoints are skipped by the manager.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves = [(jax.tree_util.keystr(path), np.asarray(leaf))
              for path, leaf in flat[0]]
    return leaves, flat[1]


def save(path: str, tree: Any, aux: Optional[dict] = None) -> None:
    path = Path(path)
    tmp = path.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    try:
        leaves, _ = _flatten_with_paths(tree)
        arrays = {f"a{i}": arr for i, (_, arr) in enumerate(leaves)}
        np.savez(tmp / "arrays.npz", **arrays)
        crc = zlib.crc32((tmp / "arrays.npz").read_bytes())
        meta = {
            "paths": [p for p, _ in leaves],
            "crc32": crc,
            "aux": aux or {},
        }
        (tmp / "meta.json").write_text(
            json.dumps(meta, default=_json_default))
    except BaseException:
        # a torn write must never leave a half-built tmp dir behind: the
        # final destination only ever appears via the atomic replace below
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    if path.exists():
        shutil.rmtree(path)
    os.replace(tmp, path)


def _json_default(o):
    if isinstance(o, np.ndarray):
        return {"__nd__": o.tolist(), "dtype": str(o.dtype)}
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, tuple):
        return list(o)
    raise TypeError(f"not jsonable: {type(o)}")


def restore(path: str, like: Any, shardings: Any = None
            ) -> Tuple[Any, dict]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings — this is where *elastic resharding* happens: the stored
    host arrays are placed with the new mesh's shardings via
    ``jax.device_put`` regardless of the mesh they were saved under."""
    path = Path(path)
    meta = json.loads((path / "meta.json").read_text())
    crc = zlib.crc32((path / "arrays.npz").read_bytes())
    if crc != meta["crc32"]:
        raise IOError(f"checkpoint {path} failed CRC validation")
    arrays = np.load(path / "arrays.npz")
    leaves = [arrays[f"a{i}"] for i in range(len(meta["paths"]))]
    treedef = jax.tree_util.tree_structure(like)
    if treedef.num_leaves != len(leaves):
        raise IOError(
            f"checkpoint {path} has {len(leaves)} leaves; expected "
            f"{treedef.num_leaves}")
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda arr, sh: jax.device_put(arr, sh), tree, shardings)
    else:
        like_leaves = jax.tree_util.tree_leaves(like)
        tree = jax.tree_util.tree_unflatten(
            treedef,
            [jax.numpy.asarray(l, dtype=ll.dtype)
             for l, ll in zip(leaves, like_leaves)])
    return tree, meta.get("aux", {})
