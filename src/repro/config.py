"""Config system for DPQuant-JAX.

Plain dataclasses (no external deps). Every run is described by a RunConfig:
model + quantization + DP + parallelism + optimizer + data. Architecture
configs live in ``repro.configs`` and register themselves in ``ARCH_REGISTRY``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# KV-cache storage formats for serving (ServeConfig.kv_fmt / CLI --kv-fmt).
# Distinct from QuantConfig.fmt (training fake-quant + logits head): the KV
# cache is *storage* quantization — deterministic round-to-nearest with one
# bfloat16 scale per written (token, kv-head) row — dequantized on read
# inside the decode-attention op (repro.quant.kv_cache).
KV_CACHE_FORMATS = ("none", "int8", "luq_fp4")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``family`` selects the model builder:
      dense_lm | moe_lm | ssm | hybrid | encdec | vlm | resnet | densenet | bert
    """
    name: str
    family: str
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    dense_ff_residual: int = 0          # arctic-style dense residual MLP width
    moe_impl: str = "dense"             # "dense" (small/smoke) | "capacity" (sharded)
    moe_capacity_factor: float = 1.25
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_chunk: int = 256
    d_inner: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    conv_width: int = 4
    # --- hybrid (RG-LRU / griffin) ---
    lru_width: int = 0
    attn_window: int = 2048
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rec","rec","attn")
    # --- enc-dec ---
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # --- vlm ---
    n_vision_tokens: int = 0
    # --- cnn / bert ---
    num_classes: int = 0
    image_size: int = 32
    in_channels: int = 3
    resnet_blocks: Tuple[int, ...] = ()
    densenet_blocks: Tuple[int, ...] = ()
    growth_rate: int = 32
    max_position: int = 512
    # --- numerics / structure ---
    mlp_activation: str = "geglu"        # geglu | swiglu | gelu | relu
    norm: str = "rmsnorm"                # rmsnorm | layernorm
    tie_embeddings: bool = True
    rope_theta: float = 10_000.0
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # attention memory discipline
    attn_chunk_q: int = 512
    attn_chunk_k: int = 1024
    ce_chunk: int = 512                  # chunked cross-entropy sequence chunk
    remat: bool = True
    scan_layers: bool = True
    # sharding-driven padding (see DESIGN.md §5)
    pad_heads_to: int = 1                # pad n_heads up to a multiple of this
    pad_vocab_to: int = 128
    # per-arch partitioner rule overrides: ((logical_name, ((axes...), ...)), ...)
    sharding_overrides: Tuple = ()

    # ------------------------------------------------------------------ #
    @property
    def padded_heads(self) -> int:
        if self.n_heads == 0:
            return 0
        return _round_up(self.n_heads, self.pad_heads_to)

    @property
    def padded_vocab(self) -> int:
        if self.vocab_size == 0:
            return 0
        return _round_up(self.vocab_size, self.pad_vocab_to)

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return self.family in ("dense_lm", "moe_lm", "ssm", "hybrid", "encdec", "vlm")

    def policy_len(self) -> int:
        """Number of schedulable layers for DPQuant."""
        if self.family == "encdec":
            return self.n_enc_layers + self.n_dec_layers
        if self.family == "resnet":
            return sum(self.resnet_blocks) + 1
        if self.family == "densenet":
            return sum(self.densenet_blocks) + len(self.densenet_blocks)
        return self.n_layers


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Low-precision simulation config (paper §6 'Low Precision Format')."""
    fmt: str = "luq_fp4"    # luq_fp4 | int4 | fp8_e4m3 | fp8_e5m2 | bf16 | none
    quantize_fwd: bool = True
    quantize_dgrad: bool = True   # paper A.12: quantize inputs of dgrad GEMM
    quantize_wgrad: bool = True   # ... and of wgrad GEMM
    stochastic: bool = True
    # Execution backend for the quantizers (repro.quant.backend dispatch):
    # "ref" = pure-jnp formats; "pallas" = fused Pallas kernels (interpret
    # mode on CPU).  Formats a backend lacks fall back to "ref" explicitly;
    # the REPRO_QUANT_BACKEND env var overrides this field globally.
    backend: str = "ref"


@dataclasses.dataclass(frozen=True)
class DPConfig:
    enabled: bool = True
    clip_norm: float = 1.0
    noise_multiplier: float = 1.0
    delta: float = 1e-5
    microbatch_size: int = 1
    # "data_parallel": each scan step vmaps microbatch_size examples per
    # data shard (mb = microbatch_size * dp_degree).  "single": mb = 1, the
    # whole mesh model-parallels one example at a time (giant MoE models
    # whose per-example gradient is itself device-memory-scale).
    microbatch_mode: str = "data_parallel"
    grad_accum_dtype: str = "float32"    # bfloat16 for 1T-scale models
    # Per-example clip implementation: "ref" = vmap-norms + einsum (the JAX
    # formulation in dp/clip.py); "fused" = flatten each microbatch's
    # per-example grads to (B, D) and run the fused Pallas clip+sum kernel
    # (one HBM pass; incompatible with partial_accum).
    clip_backend: str = "ref"
    # Per-example gradient engine (docs/ARCHITECTURE.md "DP gradient modes"):
    # "vmap"  = materialize per-example grads with vmap(grad) and clip+sum
    #           them (dp/clip.py) — O(B x params) memory, B rank-1 wgrads;
    # "ghost" = two-pass ghost-norm clipping (dp/ghost.py) — per-example
    #           norms from layer activation/cotangent Grams, then ONE
    #           scale-reweighted batched backward.  Requires a model family
    #           with ghost hooks (dense_lm, resnet, densenet); incompatible
    #           with partial_accum and clip_backend="fused"; microbatch_size
    #           is ignored (ghost_microbatch below is its memory knob).
    grad_mode: str = "vmap"
    # Ghost pass-1 chunk size (0 = whole batch in one vmapped pass): chunks
    # the norm pass with a lax.scan so pass-1 live state is one chunk of
    # activations; pass 2 stays one fused batched backward.  Numerically
    # identical (per-example quantization is chunk-invariant).
    ghost_microbatch: int = 0
    # Data-parallel ghost formulation (dp/ghost.sharded_ghost_clipped_grad_sum):
    # "auto" = shard_map over the mesh's data axes when they have degree > 1
    # and params are not model-sharded, else the single-pass GSPMD driver;
    # "on" / "off" force the choice.  Per-shard norm taps + ONE psum of the
    # clipped grad sums.
    ghost_sharded: str = "auto"
    # DPQuant analysis (paper Table 3 defaults)
    analysis_interval: int = 2       # epochs between COMPUTELOSSIMPACT runs
    analysis_reps: int = 2           # R
    analysis_batch_size: int = 32    # n_sample (paper Table 3: small probe
                                     # batches -> negligible analysis q)
    analysis_clip: float = 0.01     # C_measure
    analysis_noise: float = 0.5     # sigma_measure
    ema_alpha: float = 0.3           # EMA decay for policy scores
    beta: float = 10.0               # softmax temperature (Table 9 sweet spot)
    quant_fraction: float = 0.9      # fraction of layers quantized ("compute budget")
    compress_cross_pod: bool = False  # int8-compressed cross-pod grad reduce
    partial_accum: bool = False      # one grad all-reduce per step instead of
                                     # one per microbatch (perf variant)


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    name: str = "sgd"                # sgd | momentum | adam | adamw
    lr: float = 0.5                  # paper Table 5 uses 0.5 for DP-SGD
    momentum: float = 0.0
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    warmup_steps: int = 0
    schedule: str = "constant"       # constant | cosine | linear


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Continuous-batching serving engine knobs (see docs/SERVING.md).

    The engine allocates one fixed ``max_slots x max_seq`` KV cache up
    front; requests are admitted into free slots as they arrive and retire
    independently, so the decode batch stays full under mixed lengths.
    (The continuous-vs-oneshot choice is a CLI/benchmark concern —
    ``launch/serve.py --engine`` — not engine state.)
    """
    max_slots: int = 8               # decode batch width (slot pool size)
    max_seq: int = 256               # per-slot KV cache length
    max_new_tokens: int = 32         # default per-request generation budget
    temperature: float = 0.0         # 0 = greedy; >0 = per-slot sampling
    seed: int = 0                    # base of the sampling key schedule
    # KV-cache storage format (see KV_CACHE_FORMATS above): "none" keeps the
    # fp32/bf16 compute-dtype cache; "int8"/"luq_fp4" store quantized codes
    # plus per-(token, kv-head) bfloat16 scales and dequantize inside the
    # decode-attention op (docs/SERVING.md "Quantized cache layout").
    kv_fmt: str = "none"
    # ---- admission control / fault tolerance (docs/SERVING.md "Failure
    # model & recovery") ----
    # Per-request deadline in seconds from arrival (None = no deadline).
    # An expired queued request is retired without admission ("rejected"
    # bucket); an expired in-flight request retires with its partial tokens
    # and status "timed_out".  Overridable per request at submit().
    deadline_s: Optional[float] = None
    # Queue bound: submissions beyond this many waiting requests are shed
    # immediately (status "shed") instead of growing the queue without
    # bound.  0 = unbounded (the pre-fault-tolerance behavior).
    max_queue: int = 0
    # Retry policy for injected/detected faults (prefill dispatch failure,
    # decode dispatch failure, detected slot-cache poison): a victim is
    # re-queued up to max_retries times and replayed by re-prefilling
    # prompt + generated prefix — token-identical because sampling keys
    # derive from (request_id, position).  Exhausted retries finalize the
    # request with status "failed" and its partial tokens.
    max_retries: int = 2
    # Linear backoff: re-admission of attempt k is gated to
    # ``now + k * retry_backoff_s``.  0 = immediate re-queue.
    retry_backoff_s: float = 0.0

    def __post_init__(self):
        if self.max_slots < 1:
            raise ValueError("ServeConfig.max_slots must be >= 1")
        if self.max_seq < 2:
            raise ValueError("ServeConfig.max_seq must be >= 2")
        if self.kv_fmt not in KV_CACHE_FORMATS:
            raise ValueError(
                f"ServeConfig.kv_fmt must be one of {KV_CACHE_FORMATS}, "
                f"got {self.kv_fmt!r}")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError("ServeConfig.deadline_s must be > 0 (or None)")
        if self.max_queue < 0:
            raise ValueError("ServeConfig.max_queue must be >= 0")
        if self.max_retries < 0:
            raise ValueError("ServeConfig.max_retries must be >= 0")
        if self.retry_backoff_s < 0:
            raise ValueError("ServeConfig.retry_backoff_s must be >= 0")


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    # axis sizes follow the production mesh in launch/mesh.py


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the assigned input-shape cells."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    quant: QuantConfig = QuantConfig()
    dp: DPConfig = DPConfig()
    optim: OptimConfig = OptimConfig()
    mesh: MeshConfig = MeshConfig()
    seed: int = 0
    global_batch: int = 1024
    seq_len: int = 1024
    steps: int = 100
    steps_per_epoch: int = 10
    # "scan": compile the whole epoch into one jax.lax.scan program with
    # donated params/opt buffers (one host sync per epoch).  "loop": the
    # legacy per-step python loop (one host sync per step).
    epoch_executor: str = "scan"
    # 0 = scan the whole epoch at once; k > 0 = scan fixed-size chunks of k
    # steps (bounds the device memory held by the stacked epoch batches).
    epoch_chunk: int = 0
    # lax.scan unroll factor for the scan executor (compile time vs
    # throughput; 1 = no unrolling).
    epoch_unroll: int = 1
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 100
