"""Architecture registry: the 10 assigned archs + the paper's own models.

``get_config(arch_id)`` returns the exact full-scale ModelConfig;
``get_smoke_config(arch_id)`` a reduced same-family config for CPU tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ModelConfig

_MODULES = [
    "gemma_7b", "yi_9b", "yi_6b", "stablelm_3b", "kimi_k2_1t",
    "arctic_480b", "whisper_medium", "mamba2_130m", "recurrentgemma_9b",
    "internvl2_1b", "resnet18", "resnet50", "densenet121", "bert_snli",
]

ASSIGNED_ARCHS: List[str] = [
    "gemma-7b", "yi-9b", "stablelm-3b", "yi-6b", "kimi-k2-1t-a32b",
    "arctic-480b", "whisper-medium", "mamba2-130m", "recurrentgemma-9b",
    "internvl2-1b",
]

_REGISTRY: Dict[str, dict] = {}


def register(arch_id: str, full: ModelConfig, smoke: ModelConfig) -> None:
    _REGISTRY[arch_id] = {"full": full, "smoke": smoke}


def _load():
    if not _REGISTRY:
        for m in _MODULES:
            importlib.import_module(f"repro.configs.{m}")


def get_config(arch_id: str) -> ModelConfig:
    _load()
    return _REGISTRY[arch_id]["full"]


def get_smoke_config(arch_id: str) -> ModelConfig:
    _load()
    return _REGISTRY[arch_id]["smoke"]


def list_archs() -> List[str]:
    _load()
    return sorted(_REGISTRY)
