"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) expert d_ff=4864
vocab=32000, MoE 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf].

Heads padded 56 -> 64 for 16-way TP (zero-initialized pad heads, DESIGN.md
§5).  Dense residual MLP width taken = d_model (the hf config's dense FFN);
experts sharded over (pod, model), expert hidden over data.
"""
from repro.config import ModelConfig
from repro.configs import register

FULL = ModelConfig(
    name="arctic-480b", family="moe_lm",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=0, expert_d_ff=4864, n_experts=128, top_k=2,
    dense_ff_residual=7168,
    vocab_size=32_000, mlp_activation="swiglu", moe_impl="capacity",
    tie_embeddings=True, pad_heads_to=16,
    compute_dtype="bfloat16", param_dtype="bfloat16",
    attn_chunk_q=512, ce_chunk=512,
    sharding_overrides=(
        ("experts", (("pod", "model"), ("model",))),
        ("expert_mlp", (("data",),)),
        ("batch", (("data",),)),
    ),
)

SMOKE = ModelConfig(
    name="arctic-smoke", family="moe_lm",
    n_layers=2, d_model=48, n_heads=6, n_kv_heads=2, head_dim=8,
    d_ff=0, expert_d_ff=64, n_experts=4, top_k=2, dense_ff_residual=48,
    vocab_size=157, mlp_activation="swiglu", moe_impl="capacity",
    tie_embeddings=True, compute_dtype="float32", pad_heads_to=2,
    attn_chunk_q=16, ce_chunk=16, pad_vocab_to=16,
)

register("arctic-480b", FULL, SMOKE)
