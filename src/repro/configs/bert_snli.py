"""BERT-base for SNLI classification (paper's NLP experiment, DP-AdamW)."""
from repro.config import ModelConfig
from repro.configs import register

FULL = ModelConfig(
    name="bert-snli", family="bert",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=30_522, num_classes=3, max_position=128,
    mlp_activation="gelu", compute_dtype="float32", pad_heads_to=1,
    pad_vocab_to=2, attn_chunk_q=128, ce_chunk=128,
)

SMOKE = ModelConfig(
    name="bert-smoke", family="bert",
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=4, head_dim=12,
    d_ff=96, vocab_size=149, num_classes=3, max_position=32,
    compute_dtype="float32", attn_chunk_q=16, pad_vocab_to=16,
)

register("bert-snli", FULL, SMOKE)
