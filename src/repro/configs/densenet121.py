"""DenseNet-121 (paper model): blocks (6,12,24,16), growth 32, GroupNorm."""
from repro.config import ModelConfig
from repro.configs import register

FULL = ModelConfig(
    name="densenet121", family="densenet",
    densenet_blocks=(6, 12, 24, 16), growth_rate=32,
    num_classes=43, image_size=32, compute_dtype="float32",
)

SMOKE = ModelConfig(
    name="densenet-smoke", family="densenet",
    densenet_blocks=(2, 2), growth_rate=8,
    num_classes=10, image_size=16, compute_dtype="float32",
)

register("densenet121", FULL, SMOKE)
