"""gemma-7b [dense] — 28L d_model=3072 16H (GQA kv=16) d_ff=24576
vocab=256000, GeGLU, head_dim=256  [arXiv:2403.08295; hf]."""
from repro.config import ModelConfig
from repro.configs import register

FULL = ModelConfig(
    name="gemma-7b", family="dense_lm",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab_size=256_000, mlp_activation="geglu",
    tie_embeddings=True, pad_heads_to=16,
    compute_dtype="bfloat16", param_dtype="float32",
    attn_chunk_q=512, ce_chunk=512,
)

SMOKE = ModelConfig(
    name="gemma-7b-smoke", family="dense_lm",
    n_layers=3, d_model=96, n_heads=4, n_kv_heads=4, head_dim=24,
    d_ff=256, vocab_size=409, mlp_activation="geglu",
    tie_embeddings=True, compute_dtype="float32",
    attn_chunk_q=16, ce_chunk=16, pad_vocab_to=16,
)

register("gemma-7b", FULL, SMOKE)
