"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655; InternViT frontend STUB (256 precomputed patch embeddings)
[arXiv:2404.16821; hf].

Heads padded 14 -> 16 for TP; vocab padded to 151680 (128-multiple).
"""
from repro.config import ModelConfig
from repro.configs import register

FULL = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
    d_ff=4864, vocab_size=151_655, n_vision_tokens=256,
    mlp_activation="swiglu", tie_embeddings=True, pad_heads_to=16,
    compute_dtype="bfloat16", param_dtype="float32",
    attn_chunk_q=512, ce_chunk=256,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm",
    n_layers=2, d_model=48, n_heads=3, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=211, n_vision_tokens=4,
    mlp_activation="swiglu", tie_embeddings=True, pad_heads_to=4,
    compute_dtype="float32", attn_chunk_q=16, ce_chunk=16, pad_vocab_to=16,
)

register("internvl2-1b", FULL, SMOKE)
