"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048
vocab=163840, MoE 384 experts top-8 [arXiv:2501.kimi2; unverified].

1T total / ~32B active params.  Sharding strategy (DESIGN.md §5): expert dim
over (pod, model), expert hidden over data — 512-way parameter sharding; DP
microbatch_mode="single" (a single example's gradient is itself
device-memory scale); bf16 gradient accumulation.
"""
from repro.config import ModelConfig
from repro.configs import register

FULL = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe_lm",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=0, expert_d_ff=2048, n_experts=384, top_k=8,
    vocab_size=163_840, mlp_activation="swiglu", moe_impl="capacity",
    tie_embeddings=True, pad_heads_to=16,
    compute_dtype="bfloat16", param_dtype="bfloat16",
    attn_chunk_q=512, ce_chunk=512,
    sharding_overrides=(
        ("experts", (("pod", "model"), ("model",))),
        ("expert_mlp", (("data",),)),
        ("batch", (("data",),)),
    ),
)

SMOKE = ModelConfig(
    name="kimi-k2-smoke", family="moe_lm",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=0, expert_d_ff=96, n_experts=8, top_k=2,
    vocab_size=173, mlp_activation="swiglu", moe_impl="capacity",
    tie_embeddings=True, compute_dtype="float32",
    attn_chunk_q=16, ce_chunk=16, pad_vocab_to=16,
)

register("kimi-k2-1t-a32b", FULL, SMOKE)
