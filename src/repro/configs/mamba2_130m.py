"""mamba2-130m [ssm] — 24L d_model=768 (attn-free) vocab=50280,
ssm_state=128, SSD (state-space duality) [arXiv:2405.21060; unverified].

d_inner = 2*d_model = 1536, head_dim 64 -> 24 SSD heads, chunk 256.
Model dims replicate under TP (130M params; the divisibility fallback
leaves heads unsharded — TP is unnecessary at this size, DESIGN.md §4).
"""
from repro.config import ModelConfig
from repro.configs import register

FULL = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, d_inner=1536, ssm_heads=24, ssm_head_dim=64,
    ssm_state=128, ssm_chunk=256, conv_width=4,
    vocab_size=50_280,
    compute_dtype="bfloat16", param_dtype="float32",
    ce_chunk=512,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=64, d_inner=128, ssm_heads=4, ssm_head_dim=32,
    ssm_state=16, ssm_chunk=16, conv_width=4,
    vocab_size=127, compute_dtype="float32", ce_chunk=16, pad_vocab_to=16,
)

register("mamba2-130m", FULL, SMOKE)
