"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000; RG-LRU + local attention (window 2048), pattern 1:2
attn:recurrent -> (rec, rec, attn) x 12 + (rec, rec) [arXiv:2402.19427]."""
from repro.config import ModelConfig
from repro.configs import register

FULL = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, lru_width=4096, conv_width=4, attn_window=2048,
    block_pattern=("rec", "rec", "attn"),
    vocab_size=256_000, mlp_activation="geglu",
    tie_embeddings=True, pad_heads_to=16,
    compute_dtype="bfloat16", param_dtype="float32",
    attn_chunk_q=512, ce_chunk=512,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke", family="hybrid",
    n_layers=8, d_model=48, n_heads=4, n_kv_heads=1, head_dim=12,
    d_ff=96, lru_width=64, conv_width=4, attn_window=8,
    block_pattern=("rec", "rec", "attn"),
    vocab_size=151, compute_dtype="float32",
    attn_chunk_q=8, ce_chunk=16, pad_vocab_to=16,
)

register("recurrentgemma-9b", FULL, SMOKE)
