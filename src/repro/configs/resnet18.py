"""ResNet-18 — the paper's primary model (He et al. 2015), GroupNorm."""
from repro.config import ModelConfig
from repro.configs import register

FULL = ModelConfig(
    name="resnet18", family="resnet", resnet_blocks=(2, 2, 2, 2),
    num_classes=43, image_size=32, compute_dtype="float32",
)

SMOKE = ModelConfig(
    name="resnet18-smoke", family="resnet", resnet_blocks=(1, 1),
    num_classes=10, image_size=16, compute_dtype="float32",
)

register("resnet18", FULL, SMOKE)
