"""ResNet-50 (paper model), bottleneck blocks, GroupNorm."""
from repro.config import ModelConfig
from repro.configs import register

FULL = ModelConfig(
    name="resnet50", family="resnet", resnet_blocks=(3, 4, 6, 3),
    num_classes=43, image_size=32, compute_dtype="float32",
)

SMOKE = ModelConfig(
    name="resnet50-smoke", family="resnet", resnet_blocks=(2, 2, 2, 2),
    num_classes=10, image_size=16, compute_dtype="float32",
)

register("resnet50", FULL, SMOKE)
