"""stablelm-3b [dense] — 32L d_model=2560 32H (MHA kv=32) d_ff=6912
vocab=50304 [hf:stabilityai/stablelm-2-1_6b; unverified]."""
from repro.config import ModelConfig
from repro.configs import register

FULL = ModelConfig(
    name="stablelm-3b", family="dense_lm",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=6912, vocab_size=50_304, mlp_activation="swiglu",
    tie_embeddings=False, pad_heads_to=16,
    compute_dtype="bfloat16", param_dtype="float32",
    attn_chunk_q=512, ce_chunk=512,
)

SMOKE = ModelConfig(
    name="stablelm-3b-smoke", family="dense_lm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=192, vocab_size=199, mlp_activation="swiglu",
    tie_embeddings=False, compute_dtype="float32",
    attn_chunk_q=16, ce_chunk=16, pad_vocab_to=16,
)

register("stablelm-3b", FULL, SMOKE)
