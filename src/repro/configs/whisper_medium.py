"""whisper-medium [audio] — 24L enc + 24L dec, d_model=1024 16H (kv=16)
d_ff=4096 vocab=51865; conv frontend STUB (precomputed frame embeddings)
[arXiv:2212.04356; unverified]."""
from repro.config import ModelConfig
from repro.configs import register

FULL = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, n_enc_layers=24, n_dec_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=51_865, mlp_activation="gelu",
    tie_embeddings=True, pad_heads_to=16,
    compute_dtype="bfloat16", param_dtype="float32",
    attn_chunk_q=512, ce_chunk=512,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, n_dec_layers=2,
    d_model=48, n_heads=4, n_kv_heads=4, head_dim=12,
    d_ff=128, vocab_size=131, mlp_activation="gelu",
    tie_embeddings=True, compute_dtype="float32",
    attn_chunk_q=16, ce_chunk=16, pad_vocab_to=16,
)

register("whisper-medium", FULL, SMOKE)
