"""yi-9b [dense] — 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000,
llama-arch GQA, SwiGLU [arXiv:2403.04652; hf]."""
from repro.config import ModelConfig
from repro.configs import register

FULL = ModelConfig(
    name="yi-9b", family="dense_lm",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab_size=64_000, mlp_activation="swiglu",
    tie_embeddings=False, pad_heads_to=16,
    compute_dtype="bfloat16", param_dtype="float32",
    attn_chunk_q=512, ce_chunk=512,
)

SMOKE = ModelConfig(
    name="yi-9b-smoke", family="dense_lm",
    n_layers=3, d_model=96, n_heads=8, n_kv_heads=2, head_dim=12,
    d_ff=256, vocab_size=311, mlp_activation="swiglu",
    tie_embeddings=False, compute_dtype="float32",
    attn_chunk_q=16, ce_chunk=16, pad_vocab_to=16,
)

register("yi-9b", FULL, SMOKE)
