"""DPQuant core — the paper's primary contribution.

Policies (per-layer quantization flag sets), Algorithm 1 (COMPUTELOSSIMPACT,
the DP loss-sensitivity estimator), Algorithm 2 (SELECTTARGETS, softmax
sampling without replacement), and the epoch scheduler tying them together.
"""
from repro.core.loss_impact import compute_loss_impact
from repro.core.policy import (QuantPolicy, empty_policy, full_policy,
                               random_policy, singleton_policies,
                               union_policy)
from repro.core.scheduler import DPQuantScheduler
from repro.core.selection import (sample_without_replacement, select_targets,
                                  selection_probs)

__all__ = [
    "compute_loss_impact", "QuantPolicy", "empty_policy", "full_policy",
    "random_policy", "singleton_policies", "union_policy",
    "DPQuantScheduler", "sample_without_replacement", "select_targets",
    "selection_probs",
]
