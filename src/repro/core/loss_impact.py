"""Algorithm 1 — COMPUTELOSSIMPACT (paper §5.2–5.4).

For each candidate policy p (and the no-quantization baseline p0):
  restore the model snapshot, run R DP-SGD iterations on the sampled batch
  under policy p, record the average loss.  The loss-difference vector
  R[p] = avg_loss[p] - avg_loss[p0] is then *privatized* as a Sampled
  Gaussian Mechanism: clipped to l2 norm C_measure, Gaussian noise
  N(0, sigma^2 C^2) added (step 3), and folded into an EMA of per-policy
  scores (step 4 — post-processing, no extra privacy cost).

Privacy accounting (Prop. 2): one SGM step at rate q = |B| / |D| and noise
scale sigma_measure per invocation, charged to the same RDP accountant as
training, labelled "analysis" so Fig. 3's fractions can be reported.

The inner DP-SGD probe updates a *throwaway copy* of the model (RESTOREMODEL
in the paper's pseudocode == we simply never write the probe params back).
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DPConfig
from repro.core.policy import QuantPolicy
from repro.dp.accountant import RDPAccountant


def compute_loss_impact(
    *,
    probe_step: Callable,       # (params, opt_state, batch, rng, flags) ->
                                #   (params, opt_state, metrics{loss})
    params,
    opt_state,
    policies: Sequence[QuantPolicy],
    batches: Sequence[dict],    # |R| sampled batches (reused across policies)
    reps: int,
    seed: int,
    measure_clip: float,
    measure_noise: float,
    sample_rate: float,
    accountant: Optional[RDPAccountant],
    ema_scores: Optional[np.ndarray],
    ema_alpha: float,
    baseline_flags: Optional[jnp.ndarray] = None,
) -> np.ndarray:
    """Returns updated EMA scores (one per policy).  Host-side orchestration;
    each probe step is the jitted train step."""
    n_layers = policies[0].n_layers
    p0_flags = (baseline_flags if baseline_flags is not None
                else jnp.zeros((n_layers,), jnp.float32))

    def avg_loss_under(flags) -> float:
        p, o = params, opt_state           # RESTOREMODEL: fresh copy per policy
        total = 0.0
        for r in range(min(reps, len(batches))):
            p, o, metrics = probe_step(p, o, batches[r],
                                       jnp.uint32(seed + r), flags)
            total += float(metrics["loss"])
        return total / max(min(reps, len(batches)), 1)

    base = avg_loss_under(p0_flags)
    diffs = np.array([avg_loss_under(pol.flags()) - base for pol in policies],
                     np.float64)

    # ---- step 3: privatize (clip to C, add N(0, sigma^2 C^2)) ----
    norm = float(np.linalg.norm(diffs))
    clipped = diffs * min(1.0, measure_clip / max(norm, 1e-12))
    noise_key = jax.random.PRNGKey(seed + 10_007)
    noise = np.asarray(jax.random.normal(noise_key, (len(policies),),
                                         jnp.float32), np.float64)
    privatized = clipped + measure_noise * measure_clip * noise

    # ---- privacy accounting: one SGM step ----
    if accountant is not None:
        accountant.step(noise_multiplier=measure_noise,
                        sample_rate=sample_rate, steps=1, label="analysis")

    # ---- step 4: EMA update (post-processing) ----
    if ema_scores is None:
        return privatized.astype(np.float64)
    return (1.0 - ema_alpha) * np.asarray(ema_scores) + ema_alpha * privatized
