"""Quantization policies (paper §5.2).

A *policy* is a set of layers to run quantized.  DPQuant's estimator scores
candidate policies; Algorithm 2 samples ``m`` of them and quantizes the union
of their layers.  The default candidate set is one singleton policy per layer
(so the score of policy i estimates layer i's loss sensitivity R(l_i)); for
very deep nets layers can be grouped.

Policies materialize as traced ``(n_layers,)`` float {0,1} flag vectors —
changing the policy never recompiles the step function.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """An immutable set of layer indices to quantize."""
    layers: Tuple[int, ...]
    n_layers: int

    def flags(self) -> jnp.ndarray:
        f = np.zeros((self.n_layers,), np.float32)
        f[list(self.layers)] = 1.0
        return jnp.asarray(f)

    def __len__(self):
        return len(self.layers)


def full_policy(n_layers: int) -> QuantPolicy:
    return QuantPolicy(tuple(range(n_layers)), n_layers)


def empty_policy(n_layers: int) -> QuantPolicy:
    return QuantPolicy((), n_layers)


def singleton_policies(n_layers: int, group_size: int = 1) -> List[QuantPolicy]:
    """Candidate policy set P: one policy per layer (or per group)."""
    out = []
    for start in range(0, n_layers, group_size):
        layers = tuple(range(start, min(start + group_size, n_layers)))
        out.append(QuantPolicy(layers, n_layers))
    return out


def union_policy(policies: Sequence[QuantPolicy], n_layers: int) -> QuantPolicy:
    layers = sorted({l for p in policies for l in p.layers})
    return QuantPolicy(tuple(layers), n_layers)


def random_policy(n_layers: int, k: int, rng: np.random.RandomState) -> QuantPolicy:
    """A uniformly random k-subset — the paper's static random baseline."""
    layers = tuple(sorted(rng.choice(n_layers, size=k, replace=False).tolist()))
    return QuantPolicy(layers, n_layers)
