"""DPQuantScheduler — the paper's full mechanism, orchestrated.

Per epoch e:
  * if e % analysis_interval == 0: run COMPUTELOSSIMPACT (Algorithm 1) on a
    Poisson-sampled batch -> update EMA scores, charge one "analysis" SGM
    step to the accountant;
  * SELECTTARGETS (Algorithm 2): sample m policies from softmax(-beta *
    normalized EMA) without replacement, quantize the union of their layers,
    sized to the compute budget (quant_fraction * n_layers).

Modes:
  * mode="dpquant"   PLS + LLP (the full method)
  * mode="pls"       probabilistic layer sampling only (uniform scores)
  * mode="static"    a fixed random subset chosen once (the paper's baseline)

State (EMA scores, RNG, current policy) is checkpointable.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DPConfig
from repro.core import selection
from repro.core.loss_impact import compute_loss_impact
from repro.core.policy import (QuantPolicy, empty_policy, random_policy,
                               singleton_policies, union_policy)
from repro.dp.accountant import RDPAccountant


@dataclasses.dataclass
class DPQuantScheduler:
    n_layers: int
    dp: DPConfig
    mode: str = "dpquant"                 # dpquant | pls | static
    group_size: int = 1
    seed: int = 0

    def __post_init__(self):
        self.policies = singleton_policies(self.n_layers, self.group_size)
        self.scores = np.zeros((len(self.policies),), np.float64)
        self._rng = np.random.RandomState(self.seed)
        self._static: Optional[QuantPolicy] = None
        self.current: QuantPolicy = empty_policy(self.n_layers)
        self.n_analyses = 0

    # ------------------------------------------------------------------ #
    @property
    def k_quantized(self) -> int:
        return int(round(self.dp.quant_fraction * self.n_layers))

    def _m_policies(self) -> int:
        """#policies to sample so the union covers ~k layers."""
        per = max(1, self.group_size)
        return max(1, int(round(self.k_quantized / per)))

    # ------------------------------------------------------------------ #
    def maybe_analyze(self, *, probe_step: Callable, params, opt_state,
                      batches: Sequence[dict], sample_rate: float,
                      accountant: Optional[RDPAccountant],
                      epoch: int, seed: int) -> bool:
        """Run Algorithm 1 if due this epoch. Returns True if it ran."""
        if self.mode != "dpquant":
            return False
        if epoch % max(self.dp.analysis_interval, 1) != 0:
            return False
        self.scores = compute_loss_impact(
            probe_step=probe_step, params=params, opt_state=opt_state,
            policies=self.policies, batches=batches,
            reps=self.dp.analysis_reps, seed=seed,
            measure_clip=self.dp.analysis_clip,
            measure_noise=self.dp.analysis_noise,
            sample_rate=sample_rate, accountant=accountant,
            ema_scores=self.scores if self.n_analyses else None,
            ema_alpha=self.dp.ema_alpha)
        self.n_analyses += 1
        return True

    def select(self, epoch: int) -> QuantPolicy:
        """Pick this epoch's policy (Algorithm 2 / PLS / static)."""
        k = self.k_quantized
        if self.mode == "static":
            if self._static is None:
                self._static = random_policy(self.n_layers, k, self._rng)
            self.current = self._static
        elif self.mode == "pls":
            # uniform scores -> pure rotation
            probs = np.full((len(self.policies),), 1.0 / len(self.policies))
            idx = selection.sample_without_replacement(
                probs, self._m_policies(), self._rng)
            self.current = union_policy([self.policies[i] for i in idx],
                                        self.n_layers)
        else:
            self.current = selection.select_targets(
                self.scores, self.policies, self.dp.beta,
                self._m_policies(), self._rng, self.n_layers)
        return self.current

    def flags(self) -> jnp.ndarray:
        return self.current.flags()

    # ------------------------------------------------------------------ #
    def state_dict(self) -> dict:
        return {
            "scores": self.scores.tolist(),
            "rng_state": self._rng.get_state(),
            "current_layers": list(self.current.layers),
            "static_layers": (list(self._static.layers)
                              if self._static else None),
            "n_analyses": self.n_analyses,
        }

    def load_state_dict(self, state: dict) -> None:
        self.scores = np.asarray(state["scores"], np.float64)
        self._rng.set_state(state["rng_state"])
        self.current = QuantPolicy(tuple(state["current_layers"]),
                                   self.n_layers)
        if state.get("static_layers") is not None:
            self._static = QuantPolicy(tuple(state["static_layers"]),
                                       self.n_layers)
        self.n_analyses = int(state["n_analyses"])
