"""Algorithm 2 — SELECTTARGETS (paper A.15).

Given EMA'd loss-impact scores L[p] for each candidate policy:
  1. min-max normalize v = (L - min) / (max - min)
  2. pi = softmax(-beta * v)
  3. sample m policies WITHOUT replacement from pi (multinomial)
  4. return the union of their layer sets.

beta -> 0 recovers pure probabilistic layer sampling (PLS);
beta -> inf recovers deterministic lowest-impact-first selection.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.core.policy import QuantPolicy, union_policy


def selection_probs(scores: np.ndarray, beta: float) -> np.ndarray:
    scores = np.asarray(scores, np.float64)
    lo, hi = scores.min(), scores.max()
    v = np.zeros_like(scores) if hi - lo < 1e-12 else (scores - lo) / (hi - lo)
    z = -beta * v
    z -= z.max()
    p = np.exp(z)
    return p / p.sum()


def sample_without_replacement(probs: np.ndarray, m: int,
                               rng: np.random.RandomState) -> List[int]:
    """Sequential multinomial sampling without replacement."""
    probs = probs.astype(np.float64).copy()
    chosen: List[int] = []
    m = min(m, (probs > 0).sum() if (probs > 0).any() else 0)
    for _ in range(m):
        p = probs / probs.sum()
        idx = rng.choice(len(p), p=p)
        chosen.append(int(idx))
        probs[idx] = 0.0
    return chosen


def select_targets(scores: np.ndarray, policies: Sequence[QuantPolicy],
                   beta: float, m: int, rng: np.random.RandomState,
                   n_layers: int) -> QuantPolicy:
    """Full Algorithm 2: returns the union policy of the m sampled policies."""
    probs = selection_probs(scores, beta)
    idx = sample_without_replacement(probs, m, rng)
    return union_policy([policies[i] for i in idx], n_layers)
