from repro.data.synthetic import ImageClassDataset, TokenDataset, NLIDataset
from repro.data.poisson import PoissonSampler

__all__ = ["ImageClassDataset", "TokenDataset", "NLIDataset", "PoissonSampler"]
