"""Poisson subsampling — the sampling assumption of the SGM analysis.

DP-SGD's privacy analysis (and the paper's Prop. 2) assumes each example is
included independently with probability q = B/N per step.  ``PoissonSampler``
implements that exactly; the realized batch size therefore varies around B
(we pad/trim to a fixed physical batch for jit shape stability and track the
*expected* rate in the accountant — the standard practical compromise, same
as Opacus' default).
"""
from __future__ import annotations

import numpy as np


class PoissonSampler:
    def __init__(self, dataset_size: int, batch_size: int, seed: int = 0):
        self.n = dataset_size
        self.batch_size = batch_size
        self.q = batch_size / dataset_size
        self._rng = np.random.RandomState(seed)

    def sample(self) -> np.ndarray:
        """Poisson-subsampled indices, padded/trimmed to ``batch_size``."""
        mask = self._rng.rand(self.n) < self.q
        idx = np.nonzero(mask)[0]
        if len(idx) >= self.batch_size:
            idx = idx[: self.batch_size]
        else:
            pad = self._rng.randint(0, self.n, self.batch_size - len(idx))
            idx = np.concatenate([idx, pad])
        return idx

    def sample_epoch(self, steps: int) -> np.ndarray:
        """Pre-draw ``steps`` batches as a ``(steps, batch_size)`` array.

        Consumes the RNG stream exactly as ``steps`` successive ``sample()``
        calls would, so the scanned epoch executor sees bit-identical batch
        indices to the legacy per-step loop (and checkpointed sampler state
        stays interchangeable between the two executors).
        """
        return np.stack([self.sample() for _ in range(steps)])

    def state_dict(self) -> dict:
        return {"rng_state": self._rng.get_state()}

    def load_state_dict(self, state: dict) -> None:
        self._rng.set_state(state["rng_state"])
