"""Deterministic synthetic data (offline container: no downloads).

Three stream families:

* ``TokenDataset`` — integer token sequences with a planted bigram structure
  (so a language model has real signal to learn; perplexity decreases).
* ``ImageClassDataset`` — class-conditional Gaussian prototypes + noise at a
  configurable image size / #classes (GTSRB-like: 43 classes, CIFAR-like: 10),
  linearly separable enough that DP-SGD learning curves are informative.
* ``NLIDataset`` — token-pair classification (SNLI-like 3 classes) for BERT.

All are index-addressable (``get(indices)``) so the Poisson subsampler (the
DP sampling assumption) can draw arbitrary subsets.  Example generation is
deterministic per index, so every dataset memoizes generated examples: the
first epoch pays the python-loop generation cost, later epochs are a pure
numpy gather (this keeps host-side data work off the critical path of the
scanned epoch executor).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ImageClassDataset:
    n: int
    num_classes: int
    image_size: int = 32
    channels: int = 3
    noise: float = 0.6
    seed: int = 0

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        d = self.image_size * self.image_size * self.channels
        self.prototypes = rng.randn(self.num_classes, d).astype(np.float32)
        self.labels = rng.randint(0, self.num_classes, size=self.n).astype(np.int32)
        self._noise_seed = rng.randint(0, 2**31 - 1, size=self.n)
        self._cache: dict = {}

    def _example(self, idx: int) -> np.ndarray:
        x = self._cache.get(idx)
        if x is None:
            d = self.image_size * self.image_size * self.channels
            r = np.random.RandomState(self._noise_seed[idx])
            x = (self.prototypes[self.labels[idx]]
                 + self.noise * r.randn(d)).astype(np.float32)
            self._cache[idx] = x
        return x

    def get(self, indices: np.ndarray) -> dict:
        ys = self.labels[indices]
        xs = np.stack([self._example(int(idx)) for idx in indices])
        xs = xs.reshape(len(indices), self.image_size, self.image_size,
                        self.channels)
        return {"image": jnp.asarray(xs), "label": jnp.asarray(ys)}


@dataclasses.dataclass
class TokenDataset:
    """Planted-bigram language modelling data."""
    n: int
    vocab: int
    seq_len: int
    seed: int = 0

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        # a sparse deterministic "grammar": every token has 8 likely successors
        self.successors = rng.randint(0, self.vocab,
                                      size=(self.vocab, 8)).astype(np.int32)
        self._seeds = rng.randint(0, 2**31 - 1, size=self.n)
        self._cache: dict = {}

    def _example(self, idx: int) -> np.ndarray:
        seq = self._cache.get(idx)
        if seq is None:
            r = np.random.RandomState(self._seeds[idx])
            seq = np.empty(self.seq_len, np.int32)
            seq[0] = r.randint(self.vocab)
            for t in range(1, self.seq_len):
                if r.rand() < 0.9:
                    seq[t] = self.successors[seq[t - 1], r.randint(8)]
                else:
                    seq[t] = r.randint(self.vocab)
            self._cache[idx] = seq
        return seq

    def get(self, indices: np.ndarray) -> dict:
        out = np.stack([self._example(int(idx)) for idx in indices])
        return {"tokens": jnp.asarray(out)}


@dataclasses.dataclass
class NLIDataset:
    n: int
    vocab: int
    seq_len: int = 64
    num_classes: int = 3
    seed: int = 0

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        self.labels = rng.randint(0, self.num_classes, self.n).astype(np.int32)
        self.class_tokens = rng.randint(0, self.vocab,
                                        size=(self.num_classes, 16)).astype(np.int32)
        self._seeds = rng.randint(0, 2**31 - 1, size=self.n)
        self._cache: dict = {}

    def _example(self, idx: int) -> np.ndarray:
        seq = self._cache.get(idx)
        if seq is None:
            r = np.random.RandomState(self._seeds[idx])
            seq = r.randint(0, self.vocab, self.seq_len)
            # plant class-indicative tokens at random positions
            pos = r.choice(self.seq_len, 8, replace=False)
            seq[pos] = self.class_tokens[self.labels[idx], r.randint(0, 16, 8)]
            self._cache[idx] = seq.astype(np.int32)
        return self._cache[idx]

    def get(self, indices: np.ndarray) -> dict:
        ys = self.labels[indices]
        xs = np.stack([self._example(int(idx)) for idx in indices])
        return {"tokens": jnp.asarray(xs), "label": jnp.asarray(ys)}
