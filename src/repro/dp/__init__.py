from repro.dp.accountant import (
    RDPAccountant, compute_rdp_sgm, rdp_to_eps, DEFAULT_ORDERS)
from repro.dp.clip import (
    per_example_clipped_grad_sum, clip_by_global_norm, global_norm)
from repro.dp.ghost import (
    ghost_clipped_grad_sum, ghost_per_example_norms, per_example_state_bytes)
from repro.dp.noise import add_gaussian_noise
from repro.dp.engine import (
    make_dp_grad_fn, make_nondp_grad_fn, validate_grad_mode)

__all__ = [
    "RDPAccountant", "compute_rdp_sgm", "rdp_to_eps", "DEFAULT_ORDERS",
    "per_example_clipped_grad_sum", "clip_by_global_norm", "global_norm",
    "ghost_clipped_grad_sum", "ghost_per_example_norms",
    "per_example_state_bytes",
    "add_gaussian_noise", "make_dp_grad_fn", "make_nondp_grad_fn",
    "validate_grad_mode",
]
