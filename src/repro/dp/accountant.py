"""RDP accountant for the Sampled Gaussian Mechanism (SGM).

Re-implementation (no Opacus available) of the Mironov–Talwar–Zhang (2019)
RDP analysis of the SGM, with the same math as TF-privacy / Opacus:

  * integer orders alpha: binomial expansion,
        A(alpha) = sum_k C(alpha,k) (1-q)^(alpha-k) q^k exp(k(k-1)/(2 sigma^2))
  * fractional orders: the two-sided series with erfc terms,
  * RDP(alpha) = log A(alpha) / (alpha - 1),
  * RDP -> (eps, delta) via the improved conversion
        eps = rdp + log((alpha-1)/alpha) - (log(delta) + log(alpha))/(alpha-1)
    minimized over orders.

The paper (§5.4, Prop. 2) composes the *training* SGM steps with the DPQuant
*analysis* SGM steps under one accountant; we expose that as labelled
``step(..., label=...)`` entries so the analysis fraction (Fig. 3) can be
reported.  The accountant history is a plain list of tuples -> trivially
checkpointable (see repro.checkpoint).

Correctness is validated in tests against a direct numerical integration of
the Renyi divergence (tests/test_accountant.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

DEFAULT_ORDERS: Tuple[float, ...] = tuple(
    [1.25, 1.5, 1.75, 2.0, 2.25, 2.5, 3.0, 3.5, 4.0, 4.5]
    + list(range(5, 64))
    + [80.0, 96.0, 128.0, 192.0, 256.0, 384.0, 512.0]
)


# --------------------------------------------------------------------------- #
# log-space helpers
# --------------------------------------------------------------------------- #
def _log_add(a: float, b: float) -> float:
    if a == -math.inf:
        return b
    if b == -math.inf:
        return a
    hi, lo = max(a, b), min(a, b)
    return hi + math.log1p(math.exp(lo - hi))


def _log_sub(a: float, b: float) -> float:
    """log(exp(a) - exp(b)); requires a >= b."""
    if b == -math.inf:
        return a
    if a == b:
        return -math.inf
    if a < b:
        raise ValueError("log_sub requires a >= b")
    return a + math.log1p(-math.exp(b - a))


def _log_erfc(x: float) -> float:
    """Numerically stable log(erfc(x))."""
    if x < 8.0:
        return math.log(math.erfc(x))
    # Asymptotic expansion for large x.
    return (-(x ** 2) - math.log(x) - 0.5 * math.log(math.pi)
            + math.log1p(-0.5 / (x ** 2) + 0.75 / (x ** 4)))


def _log_binom(alpha: float, i: int) -> Tuple[float, float]:
    """(sign, log|binom(alpha, i)|) for real alpha, integer i >= 0."""
    sign, logv = 1.0, 0.0
    for k in range(1, i + 1):
        term = (alpha - k + 1) / k
        if term == 0.0:
            return 0.0, -math.inf
        if term < 0:
            sign = -sign
        logv += math.log(abs(term))
    return sign, logv


# --------------------------------------------------------------------------- #
# RDP of a single SGM step
# --------------------------------------------------------------------------- #
def _compute_log_a_int(q: float, sigma: float, alpha: int) -> float:
    log_a = -math.inf
    for k in range(alpha + 1):
        log_coef = (math.lgamma(alpha + 1) - math.lgamma(k + 1)
                    - math.lgamma(alpha - k + 1))
        term = (log_coef + k * math.log(q) + (alpha - k) * math.log(1 - q)
                + (k * k - k) / (2 * sigma ** 2))
        log_a = _log_add(log_a, term)
    return log_a


def _compute_log_a_frac(q: float, sigma: float, alpha: float) -> float:
    log_a0, log_a1 = -math.inf, -math.inf
    z0 = sigma ** 2 * math.log(1.0 / q - 1.0) + 0.5
    i = 0
    while True:
        sign, log_coef = _log_binom(alpha, i)
        j = alpha - i
        log_t0 = log_coef + i * math.log(q) + j * math.log(1 - q)
        log_t1 = log_coef + j * math.log(q) + i * math.log(1 - q)
        log_e0 = math.log(0.5) + _log_erfc((i - z0) / (math.sqrt(2) * sigma))
        log_e1 = math.log(0.5) + _log_erfc((z0 - j) / (math.sqrt(2) * sigma))
        log_s0 = log_t0 + (i * i - i) / (2 * sigma ** 2) + log_e0
        log_s1 = log_t1 + (j * j - j) / (2 * sigma ** 2) + log_e1
        if sign > 0:
            log_a0 = _log_add(log_a0, log_s0)
            log_a1 = _log_add(log_a1, log_s1)
        elif sign < 0:
            log_a0 = _log_sub(log_a0, log_s0)
            log_a1 = _log_sub(log_a1, log_s1)
        i += 1
        if max(log_s0, log_s1) < -30 and i > alpha:
            break
        if i > 10_000:   # safety valve
            break
    return _log_add(log_a0, log_a1)


def compute_rdp_sgm(q: float, noise_multiplier: float, alpha: float) -> float:
    """RDP (in nats) of one SGM step at order ``alpha``."""
    sigma = noise_multiplier
    if q == 0.0 or sigma == math.inf:
        return 0.0
    if sigma == 0.0:
        return math.inf
    if q == 1.0:
        # plain Gaussian mechanism
        return alpha / (2 * sigma ** 2)
    if float(alpha).is_integer():
        log_a = _compute_log_a_int(q, sigma, int(alpha))
    else:
        log_a = _compute_log_a_frac(q, sigma, alpha)
    return log_a / (alpha - 1)


def rdp_to_eps(rdp: Sequence[float], orders: Sequence[float],
               delta: float) -> Tuple[float, float]:
    """Improved RDP->(eps, delta) conversion; returns (eps, optimal order)."""
    best_eps, best_order = math.inf, orders[0]
    for r, a in zip(rdp, orders):
        if a <= 1 or math.isinf(r):
            continue
        eps = r + math.log1p(-1.0 / a) - (math.log(delta) + math.log(a)) / (a - 1)
        if eps < best_eps:
            best_eps, best_order = eps, a
    return max(best_eps, 0.0), best_order


# --------------------------------------------------------------------------- #
# Accountant
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class SGMEvent:
    noise_multiplier: float
    sample_rate: float
    steps: int
    label: str = "train"


class RDPAccountant:
    """Composes SGM steps (training + DPQuant analysis) under RDP."""

    def __init__(self, orders: Sequence[float] = DEFAULT_ORDERS):
        self.orders = tuple(orders)
        self.history: List[SGMEvent] = []
        self._rdp_cache: Dict[Tuple[float, float], Tuple[float, ...]] = {}

    # -- recording -------------------------------------------------------- #
    def step(self, *, noise_multiplier: float, sample_rate: float,
             steps: int = 1, label: str = "train") -> None:
        """Record ``steps`` SGM steps in one call.

        RDP composition is additive across steps, so charging an epoch in
        one ``steps=steps_per_epoch`` call (as the scanned epoch executor
        does) is mathematically identical to — and, with event merging
        below, produces the same history as — ``steps`` single-step calls.
        """
        if not (0.0 <= sample_rate <= 1.0):
            raise ValueError(f"sample_rate must be in [0,1], got {sample_rate}")
        if noise_multiplier < 0:
            raise ValueError("noise_multiplier must be >= 0")
        if steps < 1:
            raise ValueError(f"steps must be >= 1, got {steps}")
        if self.history and self.history[-1].noise_multiplier == noise_multiplier \
                and self.history[-1].sample_rate == sample_rate \
                and self.history[-1].label == label:
            self.history[-1].steps += steps
        else:
            self.history.append(SGMEvent(noise_multiplier, sample_rate, steps, label))

    # -- querying --------------------------------------------------------- #
    def total_steps(self, label: Optional[str] = None) -> int:
        """Total recorded SGM steps (optionally for one label)."""
        return sum(ev.steps for ev in self.history
                   if label is None or ev.label == label)

    def _rdp_single(self, sigma: float, q: float) -> Tuple[float, ...]:
        key = (sigma, q)
        if key not in self._rdp_cache:
            self._rdp_cache[key] = tuple(
                compute_rdp_sgm(q, sigma, a) for a in self.orders)
        return self._rdp_cache[key]

    def total_rdp(self, labels: Optional[Sequence[str]] = None) -> List[float]:
        total = [0.0] * len(self.orders)
        for ev in self.history:
            if labels is not None and ev.label not in labels:
                continue
            per = self._rdp_single(ev.noise_multiplier, ev.sample_rate)
            for i in range(len(total)):
                total[i] += ev.steps * per[i]
        return total

    def get_epsilon(self, delta: float,
                    labels: Optional[Sequence[str]] = None) -> Tuple[float, float]:
        return rdp_to_eps(self.total_rdp(labels), self.orders, delta)

    def analysis_fraction(self, delta: float) -> float:
        """Fraction of the spent budget attributable to DPQuant analysis
        (paper Fig. 3b), measured in RDP at the overall-optimal order."""
        total_rdp = self.total_rdp()
        _, order = rdp_to_eps(total_rdp, self.orders, delta)
        idx = self.orders.index(order)
        analysis = self.total_rdp(labels=("analysis",))[idx]
        return analysis / total_rdp[idx] if total_rdp[idx] > 0 else 0.0

    # -- checkpointing ---------------------------------------------------- #
    def state_dict(self) -> dict:
        return {"orders": list(self.orders),
                "history": [dataclasses.asdict(e) for e in self.history]}

    @classmethod
    def from_state_dict(cls, state: dict) -> "RDPAccountant":
        acc = cls(orders=tuple(state["orders"]))
        acc.history = [SGMEvent(**e) for e in state["history"]]
        return acc
