"""Per-example gradient clipping for DP-SGD (JAX-native formulation).

Memory-bounded: the batch is split into microbatches; within a microbatch
per-example gradients are computed with ``vmap(grad)``; across microbatches a
``lax.scan`` accumulates the *sum of clipped* gradients.  Peak live state is
one gradient accumulator + one microbatch of per-example gradients — O(1) in
the batch size, which is what lets the same code path lower for a 7B model at
global batch 256 on the production mesh (microbatch_size=1) *and* run fast on
CPU for the paper-scale experiments (microbatch_size=batch).
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, clip_norm: float):
    """Scale ``tree`` so its global l2 norm is at most ``clip_norm``.

    Returns (clipped_tree, norm).
    """
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda l: (l * scale).astype(l.dtype), tree), norm


def _reshape_micro(batch, n_micro: int, mb: int):
    return jax.tree_util.tree_map(
        lambda x: x.reshape((n_micro, mb) + x.shape[1:]), batch)


def _fused_clip_sum(grads, mb: int, clip_norm: float, accum_dtype):
    """Flatten per-example grads to (B, D), run the fused Pallas clip+sum
    kernel through the backend dispatcher, unflatten the summed row.

    Returns ``(clipped_sum_tree, norms)`` with the same semantics as the ref
    path: norms are per-example global l2 norms over *all* leaves, the sum
    is fp32-accumulated then cast to ``accum_dtype``.
    """
    from repro.quant import backend as qbackend
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    sizes = [int(np.prod(l.shape[1:])) for l in leaves]
    flat = jnp.concatenate(
        [l.reshape(mb, -1).astype(jnp.float32) for l in leaves], axis=1)
    clip_impl, _ = qbackend.get_clip_sum("fused")
    clipped_flat, norms = clip_impl(flat, clip_norm)
    parts = jnp.split(clipped_flat, list(np.cumsum(sizes))[:-1])
    clipped = treedef.unflatten(
        [p.reshape(l.shape[1:]).astype(accum_dtype)
         for p, l in zip(parts, leaves)])
    return clipped, norms


def per_example_clipped_grad_sum(
    loss_fn: Callable,
    params,
    batch,
    *,
    clip_norm: float,
    microbatch_size: int,
    rng: jax.Array,
    constrain: Callable = None,
    accum_dtype=jnp.float32,
    partial_accum_shards: int = 0,
    constrain_partial: Callable = None,
    clip_backend: str = "ref",
) -> Tuple[object, dict]:
    """Sum over the batch of per-example clipped gradients.

    ``loss_fn(params, example, rng)`` must return the scalar loss of ONE
    example (leading batch dim already stripped).

    ``clip_backend`` selects the clip implementation: ``"ref"`` computes
    norms leaf-by-leaf and reduces with an einsum; ``"fused"`` flattens the
    microbatch's per-example grads to one (B, D) matrix and runs the fused
    Pallas per-sample-clip kernel (one pass over the gradient matrix).
    Both produce identical metrics (norms / clip fraction / loss).

    Returns ``(grad_sum, metrics)`` where metrics carries per-example norms
    (paper Fig. 1c diagnostics), clip fraction and mean loss.
    """
    if clip_backend not in ("ref", "fused"):
        raise ValueError(f"clip_backend must be 'ref' or 'fused', "
                         f"got {clip_backend!r}")
    batch_leaves = jax.tree_util.tree_leaves(batch)
    n = batch_leaves[0].shape[0]
    mb = microbatch_size
    if n % mb != 0:
        raise ValueError(f"batch {n} not divisible by microbatch {mb}")
    n_micro = n // mb
    micro = _reshape_micro(batch, n_micro, mb)
    if constrain is not None:
        micro = constrain(micro)

    def one_example(p, ex, r):
        return loss_fn(p, ex, r)

    grad_one = jax.grad(one_example)

    # partial accumulation (perf variant): keep one partial sum per
    # data shard through the scan (no cross-shard reduction per
    # microbatch); a single all-reduce happens at the end.  Requires
    # mb to be a multiple of the shard count.
    P = partial_accum_shards if (partial_accum_shards
                                 and mb % partial_accum_shards == 0) else 0
    if P and clip_backend == "fused":
        raise ValueError("clip_backend='fused' sums the whole microbatch in "
                         "the kernel and cannot keep per-shard partial "
                         "sums; disable partial_accum or use 'ref'")

    def micro_step(carry, xs):
        acc, loss_acc = carry
        mb_batch, idx = xs
        r = jax.random.fold_in(rng, idx)
        # per-example grads within the microbatch
        def gl(ex):
            l, g = jax.value_and_grad(one_example)(params, ex, r)
            return l, g
        losses, grads = jax.vmap(gl)(mb_batch)
        if clip_backend == "fused":
            clipped, norms = _fused_clip_sum(grads, mb, clip_norm,
                                             accum_dtype)
            acc = jax.tree_util.tree_map(jnp.add, acc, clipped)
            return (acc, loss_acc + losses.sum()), norms
        # per-example global norms
        sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32)),
                         axis=tuple(range(1, l.ndim)))
                 for l in jax.tree_util.tree_leaves(grads))
        norms = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norms, 1e-12))
        if P:
            def partial(g):
                gs = g.reshape((P, mb // P) + g.shape[1:])
                sc = scale.reshape(P, mb // P)
                return jnp.einsum("pb...,pb->p...", gs.astype(jnp.float32),
                                  sc).astype(accum_dtype)
            clipped = jax.tree_util.tree_map(partial, grads)
            if constrain_partial is not None:
                clipped = constrain_partial(clipped)
        else:
            clipped = jax.tree_util.tree_map(
                lambda g: jnp.einsum(
                    "b...,b->...", g.astype(jnp.float32),
                    scale).astype(accum_dtype), grads)
        acc = jax.tree_util.tree_map(jnp.add, acc, clipped)
        return (acc, loss_acc + losses.sum()), norms

    zero_shape = (lambda p: (P,) + p.shape) if P else (lambda p: p.shape)
    zero = jax.tree_util.tree_map(
        lambda p: jnp.zeros(zero_shape(p), accum_dtype), params)
    if P and constrain_partial is not None:
        zero = constrain_partial(zero)
    (grad_sum, loss_sum), all_norms = jax.lax.scan(
        micro_step, (zero, jnp.float32(0.0)),
        (micro, jnp.arange(n_micro)))
    if P:
        grad_sum = jax.tree_util.tree_map(lambda g: g.sum(axis=0), grad_sum)

    norms = all_norms.reshape(-1)
    metrics = {
        "loss": loss_sum / n,
        "grad_norm_mean": norms.mean(),
        "grad_norm_max": norms.max(),
        "clip_fraction": (norms > clip_norm).mean(),
    }
    return grad_sum, metrics
