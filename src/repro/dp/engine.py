"""DP-SGD / DP-Adam engine: builds the private gradient function.

Combines per-example clipping (repro.dp.clip) + Gaussian noising
(repro.dp.noise).  The returned function is pure and jit/pjit friendly; the
privacy *accounting* happens host-side in the training loop (one
``accountant.step`` per optimizer step), because accounting is exact
bookkeeping, not computation.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.config import DPConfig
from repro.dp.clip import per_example_clipped_grad_sum
from repro.dp.noise import add_gaussian_noise


def make_dp_grad_fn(loss_fn: Callable, dp: DPConfig) -> Callable:
    """Returns ``dp_grad(params, batch, rng) -> (noisy_mean_grad, metrics)``.

    ``loss_fn(params, example, rng)``: scalar loss of a single example.
    """

    def dp_grad(params, batch, rng):
        clip_rng, noise_rng = jax.random.split(rng)
        batch_size = jax.tree_util.tree_leaves(batch)[0].shape[0]
        grad_sum, metrics = per_example_clipped_grad_sum(
            loss_fn, params, batch,
            clip_norm=dp.clip_norm,
            microbatch_size=dp.microbatch_size,
            rng=clip_rng)
        noisy = add_gaussian_noise(
            grad_sum, clip_norm=dp.clip_norm,
            noise_multiplier=dp.noise_multiplier,
            batch_size=batch_size, rng=noise_rng)
        return noisy, metrics

    return dp_grad


def make_nondp_grad_fn(loss_fn: Callable) -> Callable:
    """Plain (non-private) mean gradient, same signature as make_dp_grad_fn."""

    def mean_loss(params, batch, rng):
        def one(ex):
            return loss_fn(params, ex, rng)
        return jax.vmap(one)(batch).mean()

    def grad_fn(params, batch, rng):
        loss, grads = jax.value_and_grad(mean_loss)(params, batch, rng)
        return grads, {"loss": loss}

    return grad_fn
