"""DP-SGD / DP-Adam engine: builds the private gradient function.

Combines per-example clipping (repro.dp.clip) + Gaussian noising
(repro.dp.noise).  The returned function is pure and jit/pjit friendly; the
privacy *accounting* happens host-side in the training loop (one
``accountant.step`` per optimizer step), because accounting is exact
bookkeeping, not computation.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.config import DPConfig
from repro.dp.clip import per_example_clipped_grad_sum
from repro.dp.ghost import ghost_clipped_grad_sum
from repro.dp.noise import add_gaussian_noise


def validate_grad_mode(dp: DPConfig, model=None) -> None:
    """Fail fast on grad-mode knob combinations the engine cannot honor.

    ``model`` (a ``repro.models.registry.Model``) is optional; when given,
    ghost mode additionally requires the family to expose ghost hooks.
    """
    if dp.grad_mode not in ("vmap", "ghost"):
        raise ValueError(f"dp.grad_mode must be 'vmap' or 'ghost', "
                         f"got {dp.grad_mode!r}")
    if dp.grad_mode != "ghost":
        return
    if dp.ghost_microbatch < 0:
        raise ValueError(f"dp.ghost_microbatch must be >= 0, "
                         f"got {dp.ghost_microbatch}")
    if dp.ghost_sharded not in ("auto", "on", "off"):
        raise ValueError(f"dp.ghost_sharded must be 'auto', 'on' or 'off', "
                         f"got {dp.ghost_sharded!r}")
    if dp.partial_accum:
        raise ValueError("grad_mode='ghost' computes the clipped grad sum "
                         "in one reweighted backward and keeps no per-shard "
                         "partial sums; disable dp.partial_accum or use "
                         "grad_mode='vmap'")
    if dp.clip_backend == "fused":
        raise ValueError("clip_backend='fused' operates on materialized "
                         "(B, D) per-example grads, which ghost mode never "
                         "forms; use clip_backend='ref' with "
                         "grad_mode='ghost'")
    if model is not None and (model.per_example_loss is None
                              or model.ghost_mask is None):
        raise ValueError(
            f"model family {model.config.family!r} has no ghost hooks "
            f"(per_example_loss/ghost_mask); grad_mode='ghost' supports "
            f"dense_lm, resnet and densenet — use grad_mode='vmap'")


def make_dp_grad_fn(loss_fn: Callable, dp: DPConfig, *,
                    per_example_loss: Callable = None,
                    ghost_mask: Callable = None,
                    ghost_aux=None) -> Callable:
    """Returns ``dp_grad(params, batch, rng) -> (noisy_mean_grad, metrics)``.

    ``loss_fn(params, example, rng)``: scalar loss of a single example.
    With ``dp.grad_mode="ghost"``, ``per_example_loss(params, batch, rng)
    -> (B,)`` and ``ghost_mask(params) -> bool pytree`` must also be given
    (the registry ``Model`` provides both for supported families);
    ``ghost_aux`` is an optional pre-bound ``repro.dp.ghost.GhostAux``
    (full embedding/head hook coverage).
    """
    validate_grad_mode(dp)
    if dp.grad_mode == "ghost" and (per_example_loss is None
                                    or ghost_mask is None):
        raise ValueError("grad_mode='ghost' requires per_example_loss and "
                         "ghost_mask (see repro.models.registry.Model)")

    def dp_grad(params, batch, rng):
        clip_rng, noise_rng = jax.random.split(rng)
        batch_size = jax.tree_util.tree_leaves(batch)[0].shape[0]
        if dp.grad_mode == "ghost":
            grad_sum, metrics = ghost_clipped_grad_sum(
                loss_fn, per_example_loss, params, batch,
                clip_norm=dp.clip_norm, rng=clip_rng,
                hooked_mask=ghost_mask(params), aux=ghost_aux,
                ghost_microbatch=dp.ghost_microbatch)
        else:
            grad_sum, metrics = per_example_clipped_grad_sum(
                loss_fn, params, batch,
                clip_norm=dp.clip_norm,
                microbatch_size=dp.microbatch_size,
                rng=clip_rng,
                clip_backend=dp.clip_backend)
        noisy = add_gaussian_noise(
            grad_sum, clip_norm=dp.clip_norm,
            noise_multiplier=dp.noise_multiplier,
            batch_size=batch_size, rng=noise_rng)
        return noisy, metrics

    return dp_grad


def make_nondp_grad_fn(loss_fn: Callable) -> Callable:
    """Plain (non-private) mean gradient, same signature as make_dp_grad_fn."""

    def mean_loss(params, batch, rng):
        def one(ex):
            return loss_fn(params, ex, rng)
        return jax.vmap(one)(batch).mean()

    def grad_fn(params, batch, rng):
        loss, grads = jax.value_and_grad(mean_loss)(params, batch, rng)
        return grads, {"loss": loss}

    return grad_fn
