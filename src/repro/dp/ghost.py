"""Ghost-norm two-pass DP-SGD gradient engine (``DPConfig.grad_mode="ghost"``).

The vmap path (``repro.dp.clip``) materializes a full per-example gradient
pytree via ``vmap(grad)``: O(B x params) live memory and B independent
rank-1/rank-T weight-grad contractions per layer instead of one batched
GEMM.  Ghost clipping removes both costs without changing the numbers:

pass 1 — norms
    One vmapped forward+backward in which every *hooked* layer (the
    ``qeinsum`` projections and ``qconv2d`` convolutions the models already
    thread through ``repro.quant.fake_quant``) contributes its per-example
    squared weight-grad norm to a scalar "tap" input through a custom VJP,
    without ever forming the per-example weight grad:

        || x^T g ||_F^2  =  < x x^T , g g^T >        (Gram identity)

    computed as two (T, T) Grams (T = tokens/pixels per example) when
    T^2 < |w|, or as the direct (din, dout) contraction followed by an
    immediate square-reduce when the layer is small (mixed ghost norm).
    On ``backend="pallas"`` with a quantized wgrad the Gram route runs as
    ONE fused Pallas ``ghost_norm`` kernel (quantize + Gram + tap-reduce in
    a single VMEM pass — see ``repro.kernels.ghost_norm``), dispatched
    through ``repro.quant.backend``.

    Leaves not covered by a hook fall back to a vmapped *norm-only*
    per-example grad restricted to those leaves (hooked wgrads are
    DCE'd).  Dense LMs need no fallback at all: norm scales are tapped by
    a ghost ``rmsnorm`` hook, and the embedding/LM head are covered by the
    model-supplied :class:`GhostAux` hooks — a gather-side hook (token-
    equality-masked Gram of the lookup cotangents) plus a single-chunk
    LM-head hook, including the gather-head *cross term* tied embeddings
    require (the two contributions land on the same leaf, so
    ``||d_gather + d_head||^2`` has a ``2<d_gather, d_head>`` term that
    per-op scalar taps cannot see).

pass 2 — grads
    ``jax.grad`` of the scale-reweighted per-example-loss sum
    ``sum_i scale_i * loss_i`` over the *batched* (not vmapped) model:
    one standard backward at full arithmetic intensity — each layer's
    weight grad is a single (B*T, din) x (B*T, dout) GEMM that directly
    yields the clipped gradient **sum**.

Memory/scale controls
---------------------
``ghost_microbatch`` chunks pass 1 with a ``lax.scan`` over fixed-size
example chunks (tap accumulation per chunk), so pass-1 live state is one
chunk of activations instead of the whole batch — pass 2 stays one fused
batched backward, leaving its activations as the only batch-scaling
memory term (the profile of non-DP training).

``sharded_ghost_clipped_grad_sum`` is the data-parallel formulation: a
``shard_map`` over the mesh's data axes where each shard computes
per-shard squared-norm taps and its local reweighted backward, combined
by ONE ``psum`` of the clipped grad sums (norms/losses are all-gathered
for the metrics contract).  It reuses the compat-gated ``shard_map``
import from ``repro.parallel.collectives``.

Quantization parity
-------------------
The vmap path applies each stochastic quantizer per example (a (1, ...)
tensor per vmap lane, per-tensor max scaling, and an unbatched key whose
uniform draw is hoisted across lanes).  Pass 2 reproduces this exactly in
batched form: under the ghost grad context, ``fake_quant`` quantizes the
batched activation/cotangent operands *per example* (``jax.vmap`` of the
backend quantizer over example slices with the shared key — identical
draws, per-example alpha).  Because LUQ/INT4 use per-tensor max scaling
they are exactly positively-scale-invariant, so quantizing the
scale-reweighted cotangent equals reweighting the quantized cotangent:

    Q(scale_i * g_i) = scale_i * Q(g_i)

which is what makes the one-backward reweighting produce the same clipped
sums as the vmap path to fp32 tolerance *with stochastic quantization
enabled*.  Per-example quantization is also chunk-invariant, which is why
``ghost_microbatch`` and the sharded driver leave the numbers unchanged.
Deterministic relative-rounding formats (fp8/bf16) are only approximately
scale-invariant (deviation bounded by the format's relative precision);
``none`` is exact.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------- #
# trace-time context: which ghost pass (if any) the model is being traced for
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class _NormCtx:
    """Pass 1: hooked ops add per-example squared norms to ``tap``.

    ``norm_scales`` opts the norm-scale hooks (``ghost_scale_norm`` via
    ``models/common.rmsnorm``) into the tap: only drivers whose hooked
    mask actually marks the scale leaves may enable it, otherwise the
    vmapped fallback would double-count them.
    """
    tap: jax.Array
    mode: str = "norm"
    norm_scales: bool = False


@dataclasses.dataclass
class _GradCtx:
    """Pass 2: quantizers switch to per-example (vmap-parity) semantics."""
    mode: str = "grad"


_STACK: List[object] = []


def current():
    """The active ghost context (or None) — consulted by fake_quant at
    trace time; the returned context's behavior is baked into the traced
    custom-VJP statics, so backward traces never re-read it."""
    return _STACK[-1] if _STACK else None


@contextlib.contextmanager
def norm_pass(tap: jax.Array, norm_scales: bool = False):
    _STACK.append(_NormCtx(tap=tap, norm_scales=norm_scales))
    try:
        yield
    finally:
        _STACK.pop()


@contextlib.contextmanager
def grad_pass():
    _STACK.append(_GradCtx())
    try:
        yield
    finally:
        _STACK.pop()


# --------------------------------------------------------------------------- #
# model-supplied auxiliary hooks (embedding / LM head coverage)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class GhostAux:
    """Extra pass-1 hooks for leaves whose per-example norm needs more than
    a per-op scalar tap (gather-scattered embeddings, loss-side heads, and
    — for tied embeddings — their cross term).

    ``make_taps(example) -> pytree``
        zero arrays injected additively into the model's dataflow (e.g. at
        the embedding-gather output and the single-chunk logits); their
        cotangents under ``jax.grad`` ARE the quantities the norms need.
    ``tapped_loss(params, example, rng, taps) -> (loss, fwd_aux)``
        the per-example loss with the taps injected; ``fwd_aux`` carries
        forward values the combine step needs (e.g. the final hidden rows).
    ``combine(tap_cots, fwd_aux, example) -> scalar``
        the extra per-example squared-norm contribution of the
        aux-covered leaves.
    ``covers(params) -> bool pytree``
        leaves covered by the aux hooks (and the norm-scale hooks when
        ``hook_norm_scales``); OR-ed into the driver's hooked mask.
    """
    make_taps: Callable
    tapped_loss: Callable
    combine: Callable
    covers: Callable
    hook_norm_scales: bool = False


def effective_hooked_mask(params, hooked_mask, aux: Optional[GhostAux]):
    """The op-level hook mask OR the aux-covered leaves."""
    if aux is None:
        return hooked_mask
    return jax.tree_util.tree_map(lambda a, b: bool(a) or bool(b),
                                  hooked_mask, aux.covers(params))


# --------------------------------------------------------------------------- #
# per-example squared weight-grad norms (the "ghost" in ghost clipping)
# --------------------------------------------------------------------------- #
def gram_route_wins(t: int, din: int, dout: int) -> bool:
    """The mixed-ghost-norm route rule, in ONE place: Gram when T^2 is no
    larger than the weight (direct-product) size.  Shared by
    ``_matpair_sq_norm``, the fused-kernel dispatch in ``_tap_sq_norm``
    (the pallas kernel implements only the Gram route), and the ref
    ``ghost_norm`` backend impl — so the three can never disagree."""
    return t * t <= din * dout


def _matpair_sq_norm(xmat: jax.Array, gmat: jax.Array) -> jax.Array:
    """||xmat^T gmat||_F^2 without materializing it when Grams are cheaper.

    ``xmat``: (T, Din) wgrad-GEMM input rows; ``gmat``: (T, Dout) output
    cotangent rows.  Static shape-based choice (mixed ghost norm): Gram
    route costs O(T^2 (Din + Dout)) and peaks at two (T, T) buffers; the
    direct route costs the plain wgrad GEMM but its (Din, Dout) product is
    consumed by an immediate square-reduce (transient, fuses under XLA).
    """
    xmat = xmat.astype(jnp.float32)
    gmat = gmat.astype(jnp.float32)
    if gram_route_wins(xmat.shape[0], xmat.shape[1], gmat.shape[1]):
        return jnp.vdot(xmat @ xmat.T, gmat @ gmat.T)
    dw = xmat.T @ gmat
    return jnp.sum(dw * dw)


@functools.lru_cache(maxsize=None)
def _spec_axes(spec: str) -> Tuple[str, str, str, str, str, str]:
    """Split an einsum spec into (x_term, w_term, out_term, T, din, dout).

    T = x dims not contracted into w (batch/seq/pixels), din = x dims
    shared with w, dout = w dims appearing in the output.  Covers every
    projection spec the models use (no repeated or elided letters).
    """
    lhs, out_term = spec.replace(" ", "").split("->")
    x_term, w_term = lhs.split(",")
    t_ax = "".join(c for c in x_term if c not in w_term)
    din = "".join(c for c in x_term if c in w_term)
    dout = "".join(c for c in w_term if c not in x_term)
    if set(t_ax) - set(out_term) or set(dout) - set(out_term):
        raise ValueError(f"einsum spec {spec!r} is not a ghost-hookable "
                         f"projection (x-batch or w-out dims missing from "
                         f"the output)")
    return x_term, w_term, out_term, t_ax, din, dout


def _einsum_matviews(spec: str, x: jax.Array, g: jax.Array):
    """(xmat (T, Din), gmat (T, Dout), contiguous) matrix views of the
    wgrad-GEMM operands.  ``contiguous`` is True when both views are pure
    reshapes (no axis permutation) — the condition under which uniform
    draws over the matrix view match draws over the original tensors
    elementwise (the fused-kernel RNG-parity requirement)."""
    x_term, _, out_term, t_ax, din, dout = _spec_axes(spec)
    sizes = {**dict(zip(x_term, x.shape)), **dict(zip(out_term, g.shape))}
    xmat = jnp.einsum(f"{x_term}->{t_ax}{din}", x).reshape(
        int(np.prod([sizes[c] for c in t_ax], initial=1)),
        int(np.prod([sizes[c] for c in din], initial=1)))
    gmat = jnp.einsum(f"{out_term}->{t_ax}{dout}", g).reshape(
        int(np.prod([sizes[c] for c in t_ax], initial=1)),
        int(np.prod([sizes[c] for c in dout], initial=1)))
    contiguous = (x_term == t_ax + din) and (out_term == t_ax + dout)
    return xmat, gmat, contiguous


def _einsum_sq_norm(spec: str, xq: jax.Array, gq: jax.Array) -> jax.Array:
    """Per-example ||dw||^2 of ``out = einsum(spec, x, w)`` from the wgrad
    GEMM inputs (already quantized when q_wgrad is on)."""
    xmat, gmat, _ = _einsum_matviews(spec, xq, gq)
    return _matpair_sq_norm(xmat, gmat)


def _tap_sq_norm(spec: str, x, g, seed, flag, fmt: str, q_wgrad: bool,
                 backend: str) -> jax.Array:
    """The per-example squared wgrad norm a ghost einsum hook emits.

    Quantization semantics are identical to the wgrad GEMM inputs
    (folds 4/5).  When the resolved backend natively implements the
    ``ghost_norm`` op for ``fmt`` (pallas: luq_fp4), the matrix views are
    contiguous, and the Gram route wins, the quantize + Gram + reduce
    chain collapses into the fused kernel — gated behind the same traced
    ``flag`` as ``_maybe_quant`` so DPQuant policy flips never recompile.
    """
    from repro.quant import backend as qbackend
    from repro.quant.fake_quant import _maybe_quant

    xmat, gmat, contiguous = _einsum_matviews(spec, x, g)
    gram_route = gram_route_wins(xmat.shape[0], xmat.shape[1],
                                 gmat.shape[1])
    if q_wgrad and fmt != "none":
        impl, actual = qbackend.get_impl("ghost_norm", fmt, backend)
        if actual != "ref" and contiguous and gram_route:
            kx = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(0), seed), 4)
            kg = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(0), seed), 5)
            return jax.lax.cond(
                flag > 0.5,
                lambda: impl(xmat, gmat, kx, kg),
                lambda: _matpair_sq_norm(xmat, gmat))
    xq = _maybe_quant(x, seed, 4, fmt, flag, backend) if q_wgrad else x
    gq = _maybe_quant(g, seed, 5, fmt, flag, backend) if q_wgrad else g
    return _einsum_sq_norm(spec, xq, gq)


# --------------------------------------------------------------------------- #
# ghost-hooked primitives (pass 1): qeinsum / qconv2d clones whose backward
# also emits the per-example squared wgrad norm as the tap cotangent
# --------------------------------------------------------------------------- #
@functools.lru_cache(maxsize=None)
def make_ghost_qeinsum(spec: str, fmt: str, q_fwd: bool, q_dgrad: bool,
                       q_wgrad: bool, backend: str):
    """Ghost-tapped variant of ``fake_quant._make_qeinsum``.

    Forward/dgrad/wgrad quantization is identical to the plain qeinsum
    (same folds, same keys); the extra ``tap`` argument does not affect
    the output — its cotangent is *defined* to be the per-example squared
    wgrad norm, computed from the same Q(x, fold 4) / Q(g, fold 5) inputs
    the wgrad GEMM consumes, so pass-1 norms match the vmap path's norms
    of actually-quantized per-example grads.
    """
    from repro.quant.fake_quant import _maybe_quant

    def einsum(x, w):
        return jnp.einsum(spec, x, w)

    @jax.custom_vjp
    def gqeinsum(x, w, seed, flag, tap):
        del tap
        xq = _maybe_quant(x, seed, 0, fmt, flag, backend) if q_fwd else x
        wq = _maybe_quant(w, seed, 1, fmt, flag, backend) if q_fwd else w
        return einsum(xq, wq)

    def fwd(x, w, seed, flag, tap):
        return gqeinsum(x, w, seed, flag, tap), (x, w, seed, flag)

    def bwd(res, g):
        x, w, seed, flag = res
        wq = _maybe_quant(w, seed, 2, fmt, flag, backend) if q_dgrad else w
        gq_d = _maybe_quant(g, seed, 3, fmt, flag, backend) if q_dgrad else g
        (dx,) = jax.linear_transpose(lambda t: einsum(t, wq), x)(gq_d)
        xq = _maybe_quant(x, seed, 4, fmt, flag, backend) if q_wgrad else x
        gq_w = _maybe_quant(g, seed, 5, fmt, flag, backend) if q_wgrad else g
        # dw is only consumed when a caller differentiates the hooked
        # weight through a norm pass (pass 1 never does -> DCE'd by XLA)
        (dw,) = jax.linear_transpose(lambda t: einsum(xq, t), w)(gq_w)
        dtap = _tap_sq_norm(spec, x, g, seed, flag, fmt, q_wgrad, backend)
        return dx, dw, None, None, dtap

    gqeinsum.defvjp(fwd, bwd)
    return gqeinsum


@functools.lru_cache(maxsize=None)
def make_ghost_qconv(fmt: str, q_fwd: bool, q_dgrad: bool, q_wgrad: bool,
                     strides: tuple, padding: str, dnums_key: tuple,
                     filter_hw: tuple, backend: str,
                     rhs_dilation: tuple = (1, 1), feature_groups: int = 1):
    """Ghost-tapped variant of ``fake_quant._make_qconv`` (NHWC/HWIO).

    The per-example conv wgrad is ``patches(x)^T @ g`` (unfold-einsum):
    ``conv_general_dilated_patches`` with the conv's own strides/padding
    yields one (T, kh*kw*Cin) row per output position, aligned with the
    (T, Cout) cotangent rows, and the shared ``_matpair_sq_norm`` picks
    Gram vs direct per layer.

    Dilated (``rhs_dilation != (1, 1)``) and grouped
    (``feature_groups > 1``) convolutions are outside the patches
    identity; those layers fall back *per layer* to the direct norm of
    the per-example wgrad the backward already computes (``sum(dw^2)`` —
    exact, since pass 1 runs one example per vmap lane), instead of
    failing the whole family fast.
    """
    from repro.quant.fake_quant import _maybe_quant

    dn = jax.lax.ConvDimensionNumbers(*dnums_key)
    patches_ok = tuple(rhs_dilation) == (1, 1) and feature_groups == 1

    def conv(x, w):
        return jax.lax.conv_general_dilated(
            x, w, strides, padding, rhs_dilation=rhs_dilation,
            dimension_numbers=dn, feature_group_count=feature_groups)

    @jax.custom_vjp
    def gqconv(x, w, seed, flag, tap):
        del tap
        xq = _maybe_quant(x, seed, 0, fmt, flag, backend) if q_fwd else x
        wq = _maybe_quant(w, seed, 1, fmt, flag, backend) if q_fwd else w
        return conv(xq, wq)

    def fwd(x, w, seed, flag, tap):
        return gqconv(x, w, seed, flag, tap), (x, w, seed, flag)

    def bwd(res, g):
        x, w, seed, flag = res
        wq = _maybe_quant(w, seed, 2, fmt, flag, backend) if q_dgrad else w
        gq_d = _maybe_quant(g, seed, 3, fmt, flag, backend) if q_dgrad else g
        (dx,) = jax.linear_transpose(lambda t: conv(t, wq), x)(gq_d)
        xq = _maybe_quant(x, seed, 4, fmt, flag, backend) if q_wgrad else x
        gq_w = _maybe_quant(g, seed, 5, fmt, flag, backend) if q_wgrad else g
        (dw,) = jax.linear_transpose(lambda t: conv(xq, t), w)(gq_w)
        if patches_ok:
            patches = jax.lax.conv_general_dilated_patches(
                xq, filter_hw, strides, padding,
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            dtap = _matpair_sq_norm(patches.reshape(-1, patches.shape[-1]),
                                    gq_w.reshape(-1, gq_w.shape[-1]))
        else:
            # per-layer fallback: the backward's dw IS this example's
            # wgrad (one example per pass-1 vmap lane) — norm it directly
            dtap = jnp.sum(jnp.square(dw.astype(jnp.float32)))
        return dx, dw, None, None, dtap

    gqconv.defvjp(fwd, bwd)
    return gqconv


@functools.lru_cache(maxsize=None)
def make_ghost_scale_norm(base_fn: Callable, *static):
    """Ghost-tapped variant of an ``op(x, scale, *static)`` normalization.

    Output is bit-identical to ``base_fn``; the tap cotangent is the
    squared norm of the scale grad for the (single-example) call.  Used by
    ``models/common.rmsnorm`` when the active norm context enables
    ``norm_scales`` — per scan layer the contributions accumulate into the
    stacked leaf's total, matching the vmapped fallback exactly.
    """

    @jax.custom_vjp
    def gnorm(x, scale, tap):
        del tap
        return base_fn(x, scale, *static)

    def fwd(x, scale, tap):
        return base_fn(x, scale, *static), (x, scale)

    def bwd(res, g):
        x, scale = res
        _, vjp = jax.vjp(lambda xx, ss: base_fn(xx, ss, *static), x, scale)
        dx, dscale = vjp(g)
        dtap = jnp.sum(jnp.square(dscale.astype(jnp.float32)))
        return dx, dscale, dtap

    gnorm.defvjp(fwd, bwd)
    return gnorm


# --------------------------------------------------------------------------- #
# per-example quantization (pass 2): vmap-parity semantics on batched tensors
# --------------------------------------------------------------------------- #
def per_example_quantizer(q: Callable) -> Callable:
    """Wrap ``q(v, key)`` so a batched (B, ...) tensor is quantized exactly
    like B vmapped (1, ...) per-example tensors: per-example max scaling,
    and one hoisted uniform draw shared across examples (the key does not
    depend on the lane, so ``vmap`` hoists it — bit-identical to the vmap
    path's draws)."""

    def qpe(v, key):
        return jax.vmap(lambda vi: q(vi[None], key)[0])(v)

    return qpe


# --------------------------------------------------------------------------- #
# param partitioning: hooked (ghost-normed) vs non-hooked (vmapped fallback)
# --------------------------------------------------------------------------- #
def _mask_leaves(params, hooked_mask):
    p_leaves, treedef = jax.tree_util.tree_flatten(params)
    m_struct = jax.tree_util.tree_structure(hooked_mask)
    if m_struct != treedef:
        raise ValueError("ghost hooked_mask structure does not match params "
                         f"({m_struct} vs {treedef})")
    m_leaves = [bool(m) for m in jax.tree_util.tree_leaves(hooked_mask)]
    return p_leaves, m_leaves, treedef


def per_example_state_bytes(params, hooked_mask, batch_size: int,
                            itemsize: int = 4, aux: GhostAux = None) -> dict:
    """Analytic estimate of per-example gradient state (the memory term
    that scales with batch size) for the two grad modes.

    vmap materializes every parameter per example; ghost only materializes
    the non-hooked fallback leaves (Gram buffers are O(B * T^2) transients
    and are excluded — see benchmarks/dp_throughput.py).  With a model's
    :class:`GhostAux` the aux-covered leaves count as hooked — for dense
    LMs that drives ``params_nonhooked`` to exactly zero.
    """
    hooked_mask = effective_hooked_mask(params, hooked_mask, aux)
    p_leaves, m_leaves, _ = _mask_leaves(params, hooked_mask)
    total = sum(int(np.prod(l.shape)) for l in p_leaves)
    nonhooked = sum(int(np.prod(l.shape))
                    for l, m in zip(p_leaves, m_leaves) if not m)
    return {
        "params_total": total,
        "params_nonhooked": nonhooked,
        "vmap_bytes": batch_size * total * itemsize,
        "ghost_bytes": batch_size * nonhooked * itemsize,
    }


# --------------------------------------------------------------------------- #
# the two-pass driver
# --------------------------------------------------------------------------- #
def ghost_per_example_norms(loss_fn: Callable, params, batch, *,
                            rng: jax.Array, hooked_mask,
                            aux: Optional[GhostAux] = None,
                            microbatch: int = 0,
                            ) -> Tuple[jax.Array, jax.Array]:
    """Pass 1 alone: ``(per_example_losses, per_example_global_norms)``.

    ``loss_fn(params, example, rng)`` is the per-example loss the vmap path
    consumes; the returned norms match ``vmap(grad)`` global l2 norms (of
    the actually-quantized per-example grads) to fp32 tolerance.

    ``aux`` supplies the model's extra hooks (embedding/head coverage);
    ``microbatch > 0`` scans fixed-size example chunks instead of vmapping
    the whole batch, bounding pass-1 live memory by one chunk of
    activations (numerically identical — examples are independent).
    """
    hooked = effective_hooked_mask(params, hooked_mask, aux)
    p_leaves, m_leaves, treedef = _mask_leaves(params, hooked)
    nonhooked = [l for l, m in zip(p_leaves, m_leaves) if not m]
    norm_scales = aux is not None and aux.hook_norm_scales

    def rebuild(nh):
        it = iter(nh)
        return jax.tree_util.tree_unflatten(
            treedef,
            [l if m else next(it) for l, m in zip(p_leaves, m_leaves)])

    def one_example(ex):
        taps0 = aux.make_taps(ex) if aux is not None else None

        def tapped_loss(args, ex):
            nh, tap, ataps = args
            with norm_pass(tap, norm_scales=norm_scales):
                if aux is None:
                    return loss_fn(rebuild(nh), ex, rng), None
                return aux.tapped_loss(rebuild(nh), ex, rng, ataps)

        (loss, fwd), (g_nh, dtap, dataps) = jax.value_and_grad(
            tapped_loss, has_aux=True)((nonhooked, jnp.float32(0.0), taps0),
                                       ex)
        sq = dtap + sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in g_nh)
        if aux is not None:
            sq = sq + aux.combine(dataps, fwd, ex)
        return loss, sq

    n = jax.tree_util.tree_leaves(batch)[0].shape[0]
    if microbatch and 0 < microbatch < n:
        if n % microbatch != 0:
            raise ValueError(f"batch {n} not divisible by "
                             f"ghost_microbatch {microbatch}")
        chunks = jax.tree_util.tree_map(
            lambda x: x.reshape((n // microbatch, microbatch) + x.shape[1:]),
            batch)

        def scan_body(carry, chunk):
            losses, sqs = jax.vmap(one_example)(chunk)
            return carry, (losses, sqs)

        _, (losses, sq_norms) = jax.lax.scan(scan_body, None, chunks)
        losses = losses.reshape(-1)
        sq_norms = sq_norms.reshape(-1)
    else:
        losses, sq_norms = jax.vmap(one_example)(batch)
    return losses, jnp.sqrt(sq_norms)


def _two_pass(loss_fn, per_example_loss_fn, params, batch, *, clip_norm,
              rng, hooked_mask, aux, ghost_microbatch, constrain=None):
    """Shared core of the (un)sharded drivers: pass 1 + reweighted pass 2
    over whatever batch (or local shard) it is handed.  Returns
    ``(grads_f32_tree, losses, norms)``."""
    r = jax.random.fold_in(rng, 0)   # the vmap path's microbatch-0 fold
    losses, norms = ghost_per_example_norms(
        loss_fn, params, batch, rng=r, hooked_mask=hooked_mask, aux=aux,
        microbatch=ghost_microbatch)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norms, 1e-12))
    scale = jax.lax.stop_gradient(scale)

    pass2_batch = constrain(batch) if constrain is not None else batch

    def weighted_loss(p):
        with grad_pass():
            pel = per_example_loss_fn(p, pass2_batch, r)
        return jnp.vdot(scale, pel.astype(jnp.float32))

    grads = jax.grad(weighted_loss)(params)
    return grads, losses, norms


def _clip_metrics(losses, norms, clip_norm):
    n = losses.shape[0]
    return {
        "loss": losses.astype(jnp.float32).sum() / n,
        "grad_norm_mean": norms.mean(),
        "grad_norm_max": norms.max(),
        "clip_fraction": (norms > clip_norm).mean(),
    }


def ghost_clipped_grad_sum(
    loss_fn: Callable,
    per_example_loss_fn: Callable,
    params,
    batch,
    *,
    clip_norm: float,
    rng: jax.Array,
    hooked_mask,
    accum_dtype=jnp.float32,
    aux: Optional[GhostAux] = None,
    ghost_microbatch: int = 0,
    constrain: Callable = None,
) -> Tuple[object, dict]:
    """Sum over the batch of per-example clipped gradients, ghost style.

    ``loss_fn(params, example, rng)``: scalar loss of ONE example (the same
    callable the vmap path consumes — used for pass 1).
    ``per_example_loss_fn(params, batch, rng) -> (B,)``: batched per-example
    losses (used for pass 2's single reweighted backward).
    ``hooked_mask``: bool pytree matching ``params`` — True leaves are
    covered by ghost hooks (their norms arrive via the tap), False leaves
    go through the vmapped norm-only fallback.
    ``aux``: the model's :class:`GhostAux` (embedding/head hooks);
    ``ghost_microbatch``: pass-1 chunk size (0 = whole batch);
    ``constrain``: optional sharding constraint applied to the pass-2
    batch (the data-parallel GSPMD formulation).

    Returns ``(grad_sum, metrics)`` with the same metrics contract as
    ``repro.dp.clip.per_example_clipped_grad_sum``.
    """
    grads, losses, norms = _two_pass(
        loss_fn, per_example_loss_fn, params, batch, clip_norm=clip_norm,
        rng=rng, hooked_mask=hooked_mask, aux=aux,
        ghost_microbatch=ghost_microbatch, constrain=constrain)
    grad_sum = jax.tree_util.tree_map(lambda g: g.astype(accum_dtype), grads)
    return grad_sum, _clip_metrics(losses, norms, clip_norm)


def sharded_ghost_clipped_grad_sum(
    loss_fn: Callable,
    per_example_loss_fn: Callable,
    params,
    batch,
    *,
    clip_norm: float,
    rng: jax.Array,
    hooked_mask,
    mesh,
    data_axes: Tuple[str, ...] = ("pod", "data"),
    accum_dtype=jnp.float32,
    aux: Optional[GhostAux] = None,
    ghost_microbatch: int = 0,
) -> Tuple[object, dict]:
    """Data-parallel ghost driver: ``shard_map`` over the mesh's data axes.

    Each shard runs both passes on its local examples (per-shard
    squared-norm taps; the scales a shard's pass 2 needs are exactly its
    local examples'), then the clipped grad sums are combined with ONE
    ``psum`` — no per-microbatch reduction, mirroring ``partial_accum``'s
    communication shape.  Losses/norms are all-gathered (tiled, in shard
    order = batch order) so the metrics contract matches the unsharded
    driver bit-for-bit up to fp32 reduction order.

    Params must be replicated across ``data_axes`` (the standard DP data-
    parallel layout); model-parallel param sharding should use the GSPMD
    formulation (``ghost_clipped_grad_sum`` + batch constraint) instead.
    """
    from jax.sharding import PartitionSpec as P
    from repro.parallel.axes import partitioning_context
    from repro.parallel.collectives import compat_shard_map

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = tuple(a for a in data_axes if sizes.get(a, 1) > 1)
    if not axes:
        return ghost_clipped_grad_sum(
            loss_fn, per_example_loss_fn, params, batch,
            clip_norm=clip_norm, rng=rng, hooked_mask=hooked_mask,
            accum_dtype=accum_dtype, aux=aux,
            ghost_microbatch=ghost_microbatch)
    n = jax.tree_util.tree_leaves(batch)[0].shape[0]
    shards = int(np.prod([sizes[a] for a in axes]))
    if n % shards != 0:
        raise ValueError(f"global batch {n} not divisible by the "
                         f"{shards}-way data sharding {axes}")

    def body(p, local_batch, r):
        # logical-axis constraints are global-view annotations; inside the
        # manual (per-shard) region they must be inert
        with partitioning_context(None):
            grads, losses, norms = _two_pass(
                loss_fn, per_example_loss_fn, p, local_batch,
                clip_norm=clip_norm, rng=r, hooked_mask=hooked_mask,
                aux=aux, ghost_microbatch=ghost_microbatch)
        grads = jax.lax.psum(grads, axes)          # the one collective
        losses = jax.lax.all_gather(losses, axes, tiled=True)
        norms = jax.lax.all_gather(norms, axes, tiled=True)
        return grads, losses, norms

    fn = compat_shard_map(
        body, mesh,
        in_specs=(P(), P(axes), P()),
        out_specs=(P(), P(), P()))
    grads, losses, norms = fn(params, batch, rng)
    grad_sum = jax.tree_util.tree_map(lambda g: g.astype(accum_dtype), grads)
    return grad_sum, _clip_metrics(losses, norms, clip_norm)
