"""Ghost-norm two-pass DP-SGD gradient engine (``DPConfig.grad_mode="ghost"``).

The vmap path (``repro.dp.clip``) materializes a full per-example gradient
pytree via ``vmap(grad)``: O(B x params) live memory and B independent
rank-1/rank-T weight-grad contractions per layer instead of one batched
GEMM.  Ghost clipping removes both costs without changing the numbers:

pass 1 — norms
    One vmapped forward+backward in which every *hooked* layer (the
    ``qeinsum`` projections and ``qconv2d`` convolutions the models already
    thread through ``repro.quant.fake_quant``) contributes its per-example
    squared weight-grad norm to a scalar "tap" input through a custom VJP,
    without ever forming the per-example weight grad:

        || x^T g ||_F^2  =  < x x^T , g g^T >        (Gram identity)

    computed as two (T, T) Grams (T = tokens/pixels per example) when
    T^2 < |w|, or as the direct (din, dout) contraction followed by an
    immediate square-reduce when the layer is small (mixed ghost norm).
    Non-hooked leaves (norm scales, embeddings, heads) fall back to a
    vmapped *norm-only* per-example grad restricted to those leaves; the
    hooked layers' per-example weight grads are never requested and XLA
    dead-code-eliminates them.

pass 2 — grads
    ``jax.grad`` of the scale-reweighted per-example-loss sum
    ``sum_i scale_i * loss_i`` over the *batched* (not vmapped) model:
    one standard backward at full arithmetic intensity — each layer's
    weight grad is a single (B*T, din) x (B*T, dout) GEMM that directly
    yields the clipped gradient **sum**.

Quantization parity
-------------------
The vmap path applies each stochastic quantizer per example (a (1, ...)
tensor per vmap lane, per-tensor max scaling, and an unbatched key whose
uniform draw is hoisted across lanes).  Pass 2 reproduces this exactly in
batched form: under the ghost grad context, ``fake_quant`` quantizes the
batched activation/cotangent operands *per example* (``jax.vmap`` of the
backend quantizer over example slices with the shared key — identical
draws, per-example alpha).  Because LUQ/INT4 use per-tensor max scaling
they are exactly positively-scale-invariant, so quantizing the
scale-reweighted cotangent equals reweighting the quantized cotangent:

    Q(scale_i * g_i) = scale_i * Q(g_i)

which is what makes the one-backward reweighting produce the same clipped
sums as the vmap path to fp32 tolerance *with stochastic quantization
enabled*.  Deterministic relative-rounding formats (fp8/bf16) are only
approximately scale-invariant (deviation bounded by the format's relative
precision); ``none`` is exact.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------- #
# trace-time context: which ghost pass (if any) the model is being traced for
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class _NormCtx:
    """Pass 1: hooked ops add per-example squared norms to ``tap``."""
    tap: jax.Array
    mode: str = "norm"


@dataclasses.dataclass
class _GradCtx:
    """Pass 2: quantizers switch to per-example (vmap-parity) semantics."""
    mode: str = "grad"


_STACK: List[object] = []


def current():
    """The active ghost context (or None) — consulted by fake_quant at
    trace time; the returned context's behavior is baked into the traced
    custom-VJP statics, so backward traces never re-read it."""
    return _STACK[-1] if _STACK else None


@contextlib.contextmanager
def norm_pass(tap: jax.Array):
    _STACK.append(_NormCtx(tap=tap))
    try:
        yield
    finally:
        _STACK.pop()


@contextlib.contextmanager
def grad_pass():
    _STACK.append(_GradCtx())
    try:
        yield
    finally:
        _STACK.pop()


# --------------------------------------------------------------------------- #
# per-example squared weight-grad norms (the "ghost" in ghost clipping)
# --------------------------------------------------------------------------- #
def _matpair_sq_norm(xmat: jax.Array, gmat: jax.Array) -> jax.Array:
    """||xmat^T gmat||_F^2 without materializing it when Grams are cheaper.

    ``xmat``: (T, Din) wgrad-GEMM input rows; ``gmat``: (T, Dout) output
    cotangent rows.  Static shape-based choice (mixed ghost norm): Gram
    route costs O(T^2 (Din + Dout)) and peaks at two (T, T) buffers; the
    direct route costs the plain wgrad GEMM but its (Din, Dout) product is
    consumed by an immediate square-reduce (transient, fuses under XLA).
    """
    xmat = xmat.astype(jnp.float32)
    gmat = gmat.astype(jnp.float32)
    t = xmat.shape[0]
    if t * t <= xmat.shape[1] * gmat.shape[1]:
        return jnp.vdot(xmat @ xmat.T, gmat @ gmat.T)
    dw = xmat.T @ gmat
    return jnp.sum(dw * dw)


@functools.lru_cache(maxsize=None)
def _spec_axes(spec: str) -> Tuple[str, str, str, str, str, str]:
    """Split an einsum spec into (x_term, w_term, out_term, T, din, dout).

    T = x dims not contracted into w (batch/seq/pixels), din = x dims
    shared with w, dout = w dims appearing in the output.  Covers every
    projection spec the models use (no repeated or elided letters).
    """
    lhs, out_term = spec.replace(" ", "").split("->")
    x_term, w_term = lhs.split(",")
    t_ax = "".join(c for c in x_term if c not in w_term)
    din = "".join(c for c in x_term if c in w_term)
    dout = "".join(c for c in w_term if c not in x_term)
    if set(t_ax) - set(out_term) or set(dout) - set(out_term):
        raise ValueError(f"einsum spec {spec!r} is not a ghost-hookable "
                         f"projection (x-batch or w-out dims missing from "
                         f"the output)")
    return x_term, w_term, out_term, t_ax, din, dout


def _einsum_sq_norm(spec: str, xq: jax.Array, gq: jax.Array) -> jax.Array:
    """Per-example ||dw||^2 of ``out = einsum(spec, x, w)`` from the wgrad
    GEMM inputs (already quantized when q_wgrad is on)."""
    x_term, _, out_term, t_ax, din, dout = _spec_axes(spec)
    sizes = {**dict(zip(x_term, xq.shape)), **dict(zip(out_term, gq.shape))}
    xmat = jnp.einsum(f"{x_term}->{t_ax}{din}", xq).reshape(
        int(np.prod([sizes[c] for c in t_ax], initial=1)),
        int(np.prod([sizes[c] for c in din], initial=1)))
    gmat = jnp.einsum(f"{out_term}->{t_ax}{dout}", gq).reshape(
        int(np.prod([sizes[c] for c in t_ax], initial=1)),
        int(np.prod([sizes[c] for c in dout], initial=1)))
    return _matpair_sq_norm(xmat, gmat)


# --------------------------------------------------------------------------- #
# ghost-hooked primitives (pass 1): qeinsum / qconv2d clones whose backward
# also emits the per-example squared wgrad norm as the tap cotangent
# --------------------------------------------------------------------------- #
@functools.lru_cache(maxsize=None)
def make_ghost_qeinsum(spec: str, fmt: str, q_fwd: bool, q_dgrad: bool,
                       q_wgrad: bool, backend: str):
    """Ghost-tapped variant of ``fake_quant._make_qeinsum``.

    Forward/dgrad/wgrad quantization is identical to the plain qeinsum
    (same folds, same keys); the extra ``tap`` argument does not affect
    the output — its cotangent is *defined* to be the per-example squared
    wgrad norm, computed from the same Q(x, fold 4) / Q(g, fold 5) inputs
    the wgrad GEMM consumes, so pass-1 norms match the vmap path's norms
    of actually-quantized per-example grads.
    """
    from repro.quant.fake_quant import _maybe_quant

    def einsum(x, w):
        return jnp.einsum(spec, x, w)

    @jax.custom_vjp
    def gqeinsum(x, w, seed, flag, tap):
        del tap
        xq = _maybe_quant(x, seed, 0, fmt, flag, backend) if q_fwd else x
        wq = _maybe_quant(w, seed, 1, fmt, flag, backend) if q_fwd else w
        return einsum(xq, wq)

    def fwd(x, w, seed, flag, tap):
        return gqeinsum(x, w, seed, flag, tap), (x, w, seed, flag)

    def bwd(res, g):
        x, w, seed, flag = res
        wq = _maybe_quant(w, seed, 2, fmt, flag, backend) if q_dgrad else w
        gq_d = _maybe_quant(g, seed, 3, fmt, flag, backend) if q_dgrad else g
        (dx,) = jax.linear_transpose(lambda t: einsum(t, wq), x)(gq_d)
        xq = _maybe_quant(x, seed, 4, fmt, flag, backend) if q_wgrad else x
        gq_w = _maybe_quant(g, seed, 5, fmt, flag, backend) if q_wgrad else g
        # dw is only consumed when a caller differentiates the hooked
        # weight through a norm pass (pass 1 never does -> DCE'd by XLA)
        (dw,) = jax.linear_transpose(lambda t: einsum(xq, t), w)(gq_w)
        dtap = _einsum_sq_norm(spec, xq, gq_w)
        return dx, dw, None, None, dtap

    gqeinsum.defvjp(fwd, bwd)
    return gqeinsum


@functools.lru_cache(maxsize=None)
def make_ghost_qconv(fmt: str, q_fwd: bool, q_dgrad: bool, q_wgrad: bool,
                     strides: tuple, padding: str, dnums_key: tuple,
                     filter_hw: tuple, backend: str):
    """Ghost-tapped variant of ``fake_quant._make_qconv`` (NHWC/HWIO).

    The per-example conv wgrad is ``patches(x)^T @ g`` (unfold-einsum):
    ``conv_general_dilated_patches`` with the conv's own strides/padding
    yields one (T, kh*kw*Cin) row per output position, aligned with the
    (T, Cout) cotangent rows, and the shared ``_matpair_sq_norm`` picks
    Gram vs direct per layer.
    """
    from repro.quant.fake_quant import _maybe_quant

    dn = jax.lax.ConvDimensionNumbers(*dnums_key)

    def conv(x, w):
        return jax.lax.conv_general_dilated(x, w, strides, padding,
                                            dimension_numbers=dn)

    @jax.custom_vjp
    def gqconv(x, w, seed, flag, tap):
        del tap
        xq = _maybe_quant(x, seed, 0, fmt, flag, backend) if q_fwd else x
        wq = _maybe_quant(w, seed, 1, fmt, flag, backend) if q_fwd else w
        return conv(xq, wq)

    def fwd(x, w, seed, flag, tap):
        return gqconv(x, w, seed, flag, tap), (x, w, seed, flag)

    def bwd(res, g):
        x, w, seed, flag = res
        wq = _maybe_quant(w, seed, 2, fmt, flag, backend) if q_dgrad else w
        gq_d = _maybe_quant(g, seed, 3, fmt, flag, backend) if q_dgrad else g
        (dx,) = jax.linear_transpose(lambda t: conv(t, wq), x)(gq_d)
        xq = _maybe_quant(x, seed, 4, fmt, flag, backend) if q_wgrad else x
        gq_w = _maybe_quant(g, seed, 5, fmt, flag, backend) if q_wgrad else g
        (dw,) = jax.linear_transpose(lambda t: conv(xq, t), w)(gq_w)
        patches = jax.lax.conv_general_dilated_patches(
            xq, filter_hw, strides, padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        dtap = _matpair_sq_norm(patches.reshape(-1, patches.shape[-1]),
                                gq_w.reshape(-1, gq_w.shape[-1]))
        return dx, dw, None, None, dtap

    gqconv.defvjp(fwd, bwd)
    return gqconv


# --------------------------------------------------------------------------- #
# per-example quantization (pass 2): vmap-parity semantics on batched tensors
# --------------------------------------------------------------------------- #
def per_example_quantizer(q: Callable) -> Callable:
    """Wrap ``q(v, key)`` so a batched (B, ...) tensor is quantized exactly
    like B vmapped (1, ...) per-example tensors: per-example max scaling,
    and one hoisted uniform draw shared across examples (the key does not
    depend on the lane, so ``vmap`` hoists it — bit-identical to the vmap
    path's draws)."""

    def qpe(v, key):
        return jax.vmap(lambda vi: q(vi[None], key)[0])(v)

    return qpe


# --------------------------------------------------------------------------- #
# param partitioning: hooked (ghost-normed) vs non-hooked (vmapped fallback)
# --------------------------------------------------------------------------- #
def _mask_leaves(params, hooked_mask):
    p_leaves, treedef = jax.tree_util.tree_flatten(params)
    m_struct = jax.tree_util.tree_structure(hooked_mask)
    if m_struct != treedef:
        raise ValueError("ghost hooked_mask structure does not match params "
                         f"({m_struct} vs {treedef})")
    m_leaves = [bool(m) for m in jax.tree_util.tree_leaves(hooked_mask)]
    return p_leaves, m_leaves, treedef


def per_example_state_bytes(params, hooked_mask, batch_size: int,
                            itemsize: int = 4) -> dict:
    """Analytic estimate of per-example gradient state (the memory term
    that scales with batch size) for the two grad modes.

    vmap materializes every parameter per example; ghost only materializes
    the non-hooked fallback leaves (Gram buffers are O(B * T^2) transients
    and are excluded — see benchmarks/dp_throughput.py).
    """
    p_leaves, m_leaves, _ = _mask_leaves(params, hooked_mask)
    total = sum(int(np.prod(l.shape)) for l in p_leaves)
    nonhooked = sum(int(np.prod(l.shape))
                    for l, m in zip(p_leaves, m_leaves) if not m)
    return {
        "params_total": total,
        "params_nonhooked": nonhooked,
        "vmap_bytes": batch_size * total * itemsize,
        "ghost_bytes": batch_size * nonhooked * itemsize,
    }


# --------------------------------------------------------------------------- #
# the two-pass driver
# --------------------------------------------------------------------------- #
def ghost_per_example_norms(loss_fn: Callable, params, batch, *,
                            rng: jax.Array, hooked_mask
                            ) -> Tuple[jax.Array, jax.Array]:
    """Pass 1 alone: ``(per_example_losses, per_example_global_norms)``.

    ``loss_fn(params, example, rng)`` is the per-example loss the vmap path
    consumes; the returned norms match ``vmap(grad)`` global l2 norms (of
    the actually-quantized per-example grads) to fp32 tolerance.
    """
    p_leaves, m_leaves, treedef = _mask_leaves(params, hooked_mask)
    nonhooked = [l for l, m in zip(p_leaves, m_leaves) if not m]

    def rebuild(nh):
        it = iter(nh)
        return jax.tree_util.tree_unflatten(
            treedef,
            [l if m else next(it) for l, m in zip(p_leaves, m_leaves)])

    def tapped_loss(nh, tap, ex):
        with norm_pass(tap):
            return loss_fn(rebuild(nh), ex, rng)

    def one_example(ex):
        loss, (g_nh, dtap) = jax.value_and_grad(
            tapped_loss, argnums=(0, 1))(nonhooked, jnp.float32(0.0), ex)
        sq = dtap + sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in g_nh)
        return loss, sq

    losses, sq_norms = jax.vmap(one_example)(batch)
    return losses, jnp.sqrt(sq_norms)


def ghost_clipped_grad_sum(
    loss_fn: Callable,
    per_example_loss_fn: Callable,
    params,
    batch,
    *,
    clip_norm: float,
    rng: jax.Array,
    hooked_mask,
    accum_dtype=jnp.float32,
) -> Tuple[object, dict]:
    """Sum over the batch of per-example clipped gradients, ghost style.

    ``loss_fn(params, example, rng)``: scalar loss of ONE example (the same
    callable the vmap path consumes — used for pass 1).
    ``per_example_loss_fn(params, batch, rng) -> (B,)``: batched per-example
    losses (used for pass 2's single reweighted backward).
    ``hooked_mask``: bool pytree matching ``params`` — True leaves are
    covered by ghost hooks (their norms arrive via the tap), False leaves
    go through the vmapped norm-only fallback.

    Returns ``(grad_sum, metrics)`` with the same metrics contract as
    ``repro.dp.clip.per_example_clipped_grad_sum``; the whole batch is
    processed as one fused pass (no microbatching — flat per-example
    state is the point of the mode).
    """
    r = jax.random.fold_in(rng, 0)   # the vmap path's microbatch-0 fold

    # ---- pass 1: per-example global norms ----
    losses, norms = ghost_per_example_norms(
        loss_fn, params, batch, rng=r, hooked_mask=hooked_mask)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norms, 1e-12))
    scale = jax.lax.stop_gradient(scale)

    # ---- pass 2: one reweighted batched backward ----
    def weighted_loss(p):
        with grad_pass():
            pel = per_example_loss_fn(p, batch, r)
        return jnp.vdot(scale, pel.astype(jnp.float32))

    grads = jax.grad(weighted_loss)(params)
    grad_sum = jax.tree_util.tree_map(lambda g: g.astype(accum_dtype), grads)

    n = losses.shape[0]
    metrics = {
        "loss": losses.astype(jnp.float32).sum() / n,
        "grad_norm_mean": norms.mean(),
        "grad_norm_max": norms.max(),
        "clip_fraction": (norms > clip_norm).mean(),
    }
    return grad_sum, metrics
