"""Gaussian noise injection for DP-SGD.

Abadi et al. (2016) convention (also Opacus'): noise N(0, (sigma*C)^2) is
added to the *sum* of clipped per-example gradients, then the sum is divided
by the (expected) batch size:

    g_hat = (sum_i clip_C(g_i) + N(0, sigma^2 C^2 I)) / B

Noise is drawn with ``jax.random.normal`` from a step-derived key: the draw is
SPMD-consistent across the mesh (same key -> same global tensor regardless of
sharding), key-derived rather than time-derived so a restarted/elastically
re-meshed step reproduces bit-identical noise (see DESIGN.md §7).

Per paper A.17, noise is sampled and added in fp32 *before* any quantization.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def add_gaussian_noise(grad_sum, *, clip_norm: float, noise_multiplier: float,
                       batch_size: int, rng: jax.Array):
    """Noise the clipped-gradient sum and average: returns the DP update."""
    leaves, treedef = jax.tree_util.tree_flatten(grad_sum)
    keys = jax.random.split(rng, len(leaves))
    std = noise_multiplier * clip_norm
    noisy = [
        (l.astype(jnp.float32)
         + std * jax.random.normal(k, l.shape, jnp.float32)) / batch_size
        for l, k in zip(leaves, keys)
    ]
    return jax.tree_util.tree_unflatten(treedef, noisy)
