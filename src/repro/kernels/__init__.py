"""Pallas TPU kernels + jit'd public wrappers.

The wrappers (``luq_quantize``, ``luq_matmul``, ``clip_and_sum``,
``ghost_norm_sq``) own padding / RNG / interpret-mode plumbing and are
what the quantizer-backend dispatcher (``repro.quant.backend``) registers
under ``backend="pallas"``.  The raw kernels (``luq_quant_2d``,
``quant_matmul``, ``per_sample_clip``, ``ghost_norm_gram``) require
pre-padded tile-multiple shapes; ``ref`` holds their pure-jnp oracles.
"""
from repro.kernels.ops import (luq_quantize, luq_matmul, clip_and_sum,
                               ghost_norm_sq)
from repro.kernels.luq_quant import luq_quant_2d
from repro.kernels.quant_matmul import quant_matmul
from repro.kernels.per_sample_clip import per_sample_clip
from repro.kernels.ghost_norm import ghost_norm_gram
from repro.kernels import ref

__all__ = [
    "luq_quantize", "luq_matmul", "clip_and_sum", "ghost_norm_sq",
    "luq_quant_2d", "quant_matmul", "per_sample_clip", "ghost_norm_gram",
    "ref",
]
