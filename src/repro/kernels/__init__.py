from repro.kernels.ops import luq_quantize, luq_matmul, clip_and_sum

__all__ = ["luq_quantize", "luq_matmul", "clip_and_sum"]
