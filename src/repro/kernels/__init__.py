"""Pallas TPU kernels + jit'd public wrappers.

The wrappers (``luq_quantize``, ``luq_matmul``, ``clip_and_sum``,
``ghost_norm_sq``) own padding / RNG / interpret-mode plumbing and are
what the quantizer-backend dispatcher (``repro.quant.backend``) registers
under ``backend="pallas"``.  The raw kernels (``luq_quant_2d``,
``quant_matmul``, ``per_sample_clip``, ``ghost_norm_gram``) require
pre-padded tile-multiple shapes; ``ref`` holds their pure-jnp oracles.
"""
from repro.kernels.ops import (luq_quantize, luq_matmul, clip_and_sum,
                               ghost_norm_sq, kv_quant_rows,
                               decode_attn_fused)
from repro.kernels.luq_quant import luq_quant_2d
from repro.kernels.quant_matmul import quant_matmul
from repro.kernels.per_sample_clip import per_sample_clip
from repro.kernels.ghost_norm import ghost_norm_gram
from repro.kernels.decode_attn import decode_attn_call, kv_rowquant_2d
from repro.kernels import ref

__all__ = [
    "luq_quantize", "luq_matmul", "clip_and_sum", "ghost_norm_sq",
    "kv_quant_rows", "decode_attn_fused",
    "luq_quant_2d", "quant_matmul", "per_sample_clip", "ghost_norm_gram",
    "decode_attn_call", "kv_rowquant_2d",
    "ref",
]
