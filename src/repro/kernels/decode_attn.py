"""Pallas TPU kernels: KV-row quantization + fused decode attention.

Two kernels back the ``kv_quant`` / ``decode_attn`` ops of the quantizer
dispatch (``repro.quant.backend``) for the quantized cache formats
(``int8`` / ``luq_fp4``):

``kv_rowquant_2d``   one VMEM pass per row block: per-row amax, the
                     bf16-rounded scale, and the integer codes — the row
                     never round-trips HBM between scale computation and
                     encoding (the unfused path reads it twice).

``decode_attn_call`` one VMEM pass per (slot, kv-head) grid step: load the
                     packed code rows + their scales, decode (int8 cast /
                     fp4 nibble unpack) in registers, fold the K scales
                     into the post-QK scores and the V scales into the
                     pre-PV probabilities, mask by the slot's position,
                     softmax, PV — the dequantized cache never exists in
                     HBM and the scale multiplies land on the small
                     (g, S) score matrix instead of the (S, hd) operands.

Elementwise encode/decode math is imported from ``repro.quant.kv_cache``
— the same expressions the ref backend evaluates — so ref-vs-pallas
parity is a layout question, not a numerics question.  Wrappers that own
padding / packing / interpret-mode live in ``repro.kernels.ops``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant.kv_cache import (fp4_decode_unit, fp4_encode, fp4_row_scale,
                                  int8_encode, int8_row_scale)


# --------------------------------------------------------------------------- #
# KV-row quantization
# --------------------------------------------------------------------------- #
def _kv_rowquant_kernel(fmt, x_ref, codes_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)                    # (br, D)
    amax = jnp.max(jnp.abs(x), axis=-1)                   # (br,)
    if fmt == "int8":
        scale = int8_row_scale(amax)
        codes = int8_encode(x, scale).astype(jnp.int8)
    else:  # luq_fp4 — unpacked codes 0..15; the wrapper packs nibbles
        scale = fp4_row_scale(amax)
        codes = fp4_encode(x, scale).astype(jnp.int8)
    codes_ref[...] = codes
    scale_ref[...] = scale[:, None]


def kv_rowquant_2d(x: jax.Array, fmt: str, block_rows: int = 128,
                   interpret: bool = False):
    """``x``: (R, D) f32 rows, R a ``block_rows`` multiple, D lane-padded
    by the wrapper (zero columns never set the row amax of a nonzero row,
    and all-zero rows get scale 0 -> zero codes).  Returns ``(codes,
    scales)``: (R, D) int8 codes (luq_fp4: values 0..15, one per element —
    packing is the wrapper's job) and (R, 1) f32 scales (exact bf16
    values, cast to bf16 by the wrapper)."""
    r, d = x.shape
    assert r % block_rows == 0, (x.shape, block_rows)
    kernel = lambda *refs: _kv_rowquant_kernel(fmt, *refs)  # noqa: E731
    return pl.pallas_call(
        kernel,
        grid=(r // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((r, d), jnp.int8),
                   jax.ShapeDtypeStruct((r, 1), jnp.float32)],
        interpret=interpret,
    )(x)


# --------------------------------------------------------------------------- #
# fused decode attention over the quantized slot pool
# --------------------------------------------------------------------------- #
def _unit_rows(fmt, codes):
    """Stored code block (S, Dp) -> unscaled f32 value rows (S, hd_pad)."""
    if fmt == "int8":
        return codes.astype(jnp.float32)
    # luq_fp4: nibble-unpack in registers; even head_dim index = low nibble
    c = codes.astype(jnp.int32)
    lo = fp4_decode_unit(c & 0xF)
    hi = fp4_decode_unit((c >> 4) & 0xF)
    s, dp = codes.shape
    return jnp.stack([lo, hi], axis=-1).reshape(s, 2 * dp)


def _decode_attn_kernel(fmt, scale, q_ref, kc_ref, ks_ref, vc_ref, vs_ref,
                        pos_ref, o_ref):
    q = q_ref[0, 0].astype(jnp.float32)                   # (g, hd)
    kvals = _unit_rows(fmt, kc_ref[0, 0])                 # (S, hd)
    vvals = _unit_rows(fmt, vc_ref[0, 0])
    ks = ks_ref[...].reshape(1, -1)                       # (1, S)
    vs = vs_ref[...].reshape(1, -1)
    # QK with the K scales folded into the (g, S) score matrix
    scores = jax.lax.dot_general(q, kvals, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    scores = scores * (ks * scale)
    valid = (jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
             <= pos_ref[0, 0])
    scores = jnp.where(valid, scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    # PV with the V scales folded into the probabilities (probs * vs is
    # (g, S) — far cheaper than scaling the (S, hd) value rows)
    o_ref[0, 0] = jax.lax.dot_general(probs * vs, vvals,
                                      (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)


def decode_attn_call(q: jax.Array, k_codes: jax.Array, k_scale: jax.Array,
                     v_codes: jax.Array, v_scale: jax.Array, pos: jax.Array,
                     fmt: str, scale: float, interpret: bool = False):
    """Fused decode attention over a quantized cache, one grid step per
    (slot, kv-head).

    ``q``: (B, KV, g, hd) f32 (g and hd tile-padded); ``k_codes`` /
    ``v_codes``: (B, KV, S, Dp) stored rows (int8: Dp = hd; luq_fp4:
    Dp = hd // 2); ``k_scale``/``v_scale``: (B, KV, S) f32; ``pos``:
    (B, 1) int32 per-slot positions.  Padded S rows carry zero scales and
    indices beyond every ``pos``, so they contribute exactly zero.
    Returns (B, KV, g, hd) f32 context rows.
    """
    b, kv, g, hd = q.shape
    s = k_codes.shape[2]
    dp = k_codes.shape[3]
    kernel = lambda *refs: _decode_attn_kernel(fmt, scale, *refs)  # noqa: E731
    return pl.pallas_call(
        kernel,
        grid=(b, kv),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s, dp), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, s, dp), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), jnp.float32),
        interpret=interpret,
    )(q, k_codes, k_scale, v_codes, v_scale, pos)
