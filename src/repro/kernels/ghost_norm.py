"""Pallas TPU kernel: fused ghost-norm (quantize + Gram + tap-reduce).

The ghost-clipping norm pass needs, per hooked layer and example, the
squared Frobenius norm of the quantized wgrad GEMM

    || Q(x)^T Q(g) ||_F^2  =  < Q(x) Q(x)^T , Q(g) Q(g)^T >

(the Gram route of the mixed ghost norm).  As three XLA ops this is two
elementwise quantize dispatches (each an HBM round-trip of the operand)
plus the Gram/contract einsums.  The fused kernel streams each (T, bd)
column block of x and g through VMEM exactly once: the block is LUQ-
quantized in registers (``luq_stochastic_round`` — the same math as the
quantize kernel, so bits cannot drift), its (T, T) Gram outer-product is
accumulated into a VMEM scratch, and the final grid step reduces the two
Grams to the scalar tap with one vdot.  Quantized operands never touch
HBM.

Both operands are padded to a SHARED column-block count (zero columns
change neither Gram), so one grid axis drives both accumulations.  VMEM
holds two (T, T) f32 scratches — the caller only selects this kernel
when the Gram route wins (T^2 <= Din*Dout), which bounds T^2 by the
layer's weight size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.luq_quant import luq_stochastic_round


def _ghost_norm_kernel(x_ref, ux_ref, g_ref, ug_ref, ax_ref, ag_ref,
                       o_ref, xx_ref, gg_ref):
    j = pl.program_id(0)
    nj = pl.num_programs(0)

    @pl.when(j == 0)
    def _():
        xx_ref[...] = jnp.zeros_like(xx_ref)
        gg_ref[...] = jnp.zeros_like(gg_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    xq = luq_stochastic_round(x_ref[...].astype(jnp.float32),
                              ux_ref[...], ax_ref[0, 0])
    gq = luq_stochastic_round(g_ref[...].astype(jnp.float32),
                              ug_ref[...], ag_ref[0, 0])
    xx_ref[...] += jax.lax.dot_general(
        xq, xq, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    gg_ref[...] += jax.lax.dot_general(
        gq, gq, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _():
        o_ref[0, 0] = jnp.sum(xx_ref[...] * gg_ref[...])


def ghost_norm_gram(x: jax.Array, ux: jax.Array, g: jax.Array,
                    ug: jax.Array, alpha_x: jax.Array, alpha_g: jax.Array,
                    block_d: int = 256, interpret: bool = False) -> jax.Array:
    """x, ux: (T, D); g, ug: (T, D) — both padded to the same T (8-mult)
    and D (block_d-mult) by the wrapper; alphas: scalars.  Returns the
    (1, 1) f32 tap value ``<Q(x)Q(x)^T, Q(g)Q(g)^T>``."""
    t, d = x.shape
    assert g.shape == (t, d) and d % block_d == 0, (x.shape, g.shape)
    bd = block_d
    out = pl.pallas_call(
        _ghost_norm_kernel,
        grid=(d // bd,),
        in_specs=[
            pl.BlockSpec((t, bd), lambda j: (0, j)),
            pl.BlockSpec((t, bd), lambda j: (0, j)),
            pl.BlockSpec((t, bd), lambda j: (0, j)),
            pl.BlockSpec((t, bd), lambda j: (0, j)),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((t, t), jnp.float32),
                        pltpu.VMEM((t, t), jnp.float32)],
        interpret=interpret,
    )(x, ux, g, ug, alpha_x, alpha_g)
    return out
