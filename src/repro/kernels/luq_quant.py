"""Pallas TPU kernel: LUQ-FP4 stochastic quantizer (elementwise).

Grid tiles the (padded) 2-D view of the tensor into VMEM blocks; random bits
are an explicit input (threefry generated in-graph) so the kernel is
deterministic given the key — required for DP auditing and SPMD consistency.
The per-tensor scale alpha = max|x| is computed outside (one pass) and passed
as a (1, 1) scalar block broadcast to every tile; fusing the max would make
the kernel two-pass for no HBM saving (x is read once either way).

Block shape default (256, 256) = 256 KiB fp32 in + 256 KiB rand + 256 KiB out
per tile -> well under VMEM; lanes dim is a 128-multiple for clean VREG
layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant.formats import LUQ_EXP_LEVELS


def luq_stochastic_round(x, u, alpha):
    """The LUQ-FP4 elementwise math (f32 in/out), shared by the quantize
    and fused ghost-norm kernels so their bits cannot drift apart.
    Mirrors ``repro.quant.formats.luq_fp4`` exactly."""
    safe_alpha = jnp.where(alpha > 0, alpha, 1.0)
    sign = jnp.sign(x)
    y = jnp.abs(x) / safe_alpha
    min_level = 2.0 ** (-(LUQ_EXP_LEVELS - 1))
    p_under = y / min_level
    under = jnp.where(u < p_under, min_level, 0.0)
    ylog = jnp.log2(jnp.maximum(y, min_level))
    k = jnp.clip(jnp.floor(ylog), -(LUQ_EXP_LEVELS - 1), 0.0)
    low = jnp.exp2(k)
    high = jnp.minimum(jnp.exp2(k + 1.0), 1.0)
    p_up = (y - low) / jnp.maximum(high - low, 1e-30)
    rounded = jnp.where(u < p_up, high, low)
    q = jnp.where(y < min_level, under, rounded)
    return jnp.where(alpha > 0, sign * q * safe_alpha, 0.0)


def _luq_kernel(x_ref, u_ref, alpha_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    out = luq_stochastic_round(x, u_ref[...], alpha_ref[0, 0])
    o_ref[...] = out.astype(o_ref.dtype)


def luq_quant_2d(x: jax.Array, u: jax.Array, alpha: jax.Array,
                 block=(256, 256), interpret: bool = False) -> jax.Array:
    """x, u: (M, N) with M % block[0] == N % block[1] == 0; alpha: scalar."""
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    alpha2d = alpha.reshape(1, 1).astype(jnp.float32)
    return pl.pallas_call(
        _luq_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, u, alpha2d)
