"""Jit'd public wrappers for the Pallas kernels.

These own the plumbing the raw kernels don't: uniform-bit generation from a
PRNG key, per-tensor scale computation, padding to tile multiples, and
interpret-mode selection (CPU container -> interpret=True; on real TPUs set
``REPRO_PALLAS_INTERPRET=0`` or pass interpret=False).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.luq_quant import luq_quant_2d
from repro.kernels.per_sample_clip import per_sample_clip
from repro.kernels.quant_matmul import quant_matmul


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _pad_to(x, mult0, mult1):
    m, n = x.shape
    pm = (-m) % mult0
    pn = (-n) % mult1
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x, (m, n)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def luq_quantize(x: jax.Array, key: jax.Array, block=(256, 256),
                 interpret=None) -> jax.Array:
    """LUQ-FP4 stochastic quantization of an arbitrary-shape tensor."""
    interpret = _interpret_default() if interpret is None else interpret
    shape = x.shape
    flat = x.reshape(-1)
    # view as 2d, lanes-aligned
    n = flat.shape[0]
    cols = 256
    rows = -(-n // cols)
    flat = jnp.pad(flat, (0, rows * cols - n))
    x2 = flat.reshape(rows, cols)
    x2, _ = _pad_to(x2, block[0], block[1])
    u = jax.random.uniform(key, x2.shape, jnp.float32)
    alpha = jnp.max(jnp.abs(x.astype(jnp.float32)))
    q = luq_quant_2d(x2, u, alpha, block=block, interpret=interpret)
    return q.reshape(-1)[:n].reshape(shape).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def luq_matmul(a: jax.Array, b: jax.Array, key: jax.Array,
               block=(128, 128, 512), interpret=None) -> jax.Array:
    """Fused LUQ-quantize-both-operands matmul: (M,K) @ (K,N) -> f32."""
    interpret = _interpret_default() if interpret is None else interpret
    m, k = a.shape
    _, n = b.shape
    ka, kb = jax.random.split(key)
    ap, _ = _pad_to(a, block[0], block[2])
    bp, _ = _pad_to(b, block[2], block[1])
    ua = jax.random.uniform(ka, ap.shape, jnp.float32)
    ub = jax.random.uniform(kb, bp.shape, jnp.float32)
    alpha_a = jnp.max(jnp.abs(a.astype(jnp.float32)))
    alpha_b = jnp.max(jnp.abs(b.astype(jnp.float32)))
    out = quant_matmul(ap, bp, ua, ub, alpha_a, alpha_b, block=block,
                       interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("clip_norm", "block_d",
                                             "interpret"))
def clip_and_sum(grads: jax.Array, clip_norm: float, block_d: int = 512,
                 interpret=None):
    """Fused DP per-example clip + batch sum.

    ``grads``: (B, D) per-example gradient rows, any float dtype, any B >= 1
    and D >= 1 (D is zero-padded to a ``block_d`` multiple internally —
    zero columns change neither the row norms nor the sum, and the padding
    is stripped before returning).

    Returns ``(clipped_sum, norms)`` matching ``ref.per_sample_clip_ref``:
    ``clipped_sum`` (D,) f32 = sum_b min(1, C/||g_b||) * g[b], and ``norms``
    (B,) f32 per-example l2 norms (the clip-fraction / grad-norm
    diagnostics of paper Fig. 1c are computed from these).
    """
    interpret = _interpret_default() if interpret is None else interpret
    b, d = grads.shape
    pd = (-d) % block_d
    if pd:
        grads = jnp.pad(grads, ((0, 0), (0, pd)))
    out, norms = per_sample_clip(grads, clip_norm, block_d=block_d,
                                 interpret=interpret)
    return out[:d], norms
