"""Jit'd public wrappers for the Pallas kernels.

These own the plumbing the raw kernels don't: uniform-bit generation from a
PRNG key, per-tensor scale computation, padding to tile multiples, and
interpret-mode selection (CPU container -> interpret=True; on real TPUs set
``REPRO_PALLAS_INTERPRET=0`` or pass interpret=False).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attn import decode_attn_call, kv_rowquant_2d
from repro.kernels.ghost_norm import ghost_norm_gram
from repro.kernels.luq_quant import luq_quant_2d
from repro.kernels.per_sample_clip import per_sample_clip
from repro.kernels.quant_matmul import quant_matmul
from repro.quant import kv_cache as kvc


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _pad_to(x, mult0, mult1):
    m, n = x.shape
    pm = (-m) % mult0
    pn = (-n) % mult1
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x, (m, n)


def _luq_draw_shape(n: int, block=(256, 256)):
    """The padded 2-d view ``luq_quantize`` draws its uniforms over, for a
    tensor of ``n`` elements.  Threefry pairs the first and second halves
    of the counter array, so ``uniform(key, N)[:n] != uniform(key, (n,))``
    — the draw for element i depends on the TOTAL element count, making
    this shape part of the bit-parity contract.  Single source of truth:
    both ``luq_quantize`` and ``luq_uniform`` derive their draws from it,
    so they cannot drift apart."""
    cols = 256
    rows = -(-n // cols)
    rows += (-rows) % block[0]
    cols += (-cols) % block[1]
    return rows, cols


def luq_uniform(key, shape, block=(256, 256)) -> jax.Array:
    """The uniform draws ``luq_quantize`` consumes for a tensor of
    ``shape``, reshaped back to ``shape`` — what a fused kernel
    (``ghost_norm_sq``) uses to be bit-identical to the quantize kernel
    for the same ``(tensor, key)``."""
    n = int(np.prod(shape))
    u = jax.random.uniform(key, _luq_draw_shape(n, block), jnp.float32)
    return u.reshape(-1)[:n].reshape(shape)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def luq_quantize(x: jax.Array, key: jax.Array, block=(256, 256),
                 interpret=None) -> jax.Array:
    """LUQ-FP4 stochastic quantization of an arbitrary-shape tensor."""
    interpret = _interpret_default() if interpret is None else interpret
    shape = x.shape
    flat = x.reshape(-1)
    # view as 2d, lanes-aligned
    n = flat.shape[0]
    cols = 256
    rows = -(-n // cols)
    flat = jnp.pad(flat, (0, rows * cols - n))
    x2 = flat.reshape(rows, cols)
    x2, _ = _pad_to(x2, block[0], block[1])
    assert x2.shape == _luq_draw_shape(n, block), (x2.shape, n)
    u = jax.random.uniform(key, x2.shape, jnp.float32)
    alpha = jnp.max(jnp.abs(x.astype(jnp.float32)))
    q = luq_quant_2d(x2, u, alpha, block=block, interpret=interpret)
    return q.reshape(-1)[:n].reshape(shape).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def luq_matmul(a: jax.Array, b: jax.Array, key: jax.Array,
               block=(128, 128, 512), interpret=None) -> jax.Array:
    """Fused LUQ-quantize-both-operands matmul: (M,K) @ (K,N) -> f32."""
    interpret = _interpret_default() if interpret is None else interpret
    m, k = a.shape
    _, n = b.shape
    ka, kb = jax.random.split(key)
    ap, _ = _pad_to(a, block[0], block[2])
    bp, _ = _pad_to(b, block[2], block[1])
    ua = jax.random.uniform(ka, ap.shape, jnp.float32)
    ub = jax.random.uniform(kb, bp.shape, jnp.float32)
    alpha_a = jnp.max(jnp.abs(a.astype(jnp.float32)))
    alpha_b = jnp.max(jnp.abs(b.astype(jnp.float32)))
    out = quant_matmul(ap, bp, ua, ub, alpha_a, alpha_b, block=block,
                       interpret=interpret)
    return out[:m, :n]


# Largest row count the fused ghost-norm kernel accepts: its two (T, T)
# f32 Gram scratches must fit VMEM alongside the operand blocks
# (2 * 512^2 * 4B = 2 MiB scratch + ~2 MiB blocks, well under the
# ~16 MiB/core budget).  Above the cap the wrapper falls back to the
# unfused quantize-then-Gram composition, which XLA handles at any size.
GHOST_NORM_MAX_T = 512


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def ghost_norm_sq(x: jax.Array, g: jax.Array, key_x: jax.Array,
                  key_g: jax.Array, block_d: int = 256,
                  interpret=None) -> jax.Array:
    """Fused LUQ-quantize + Gram + tap-reduce: ``||Q(x)^T Q(g)||_F^2``.

    ``x``: (T, Din) wgrad-GEMM input rows; ``g``: (T, Dout) cotangent rows
    (the matrix views of the ghost einsum hook — contiguous reshapes of
    the original operands, so ``luq_uniform`` over the matrix view is
    elementwise identical to the draws ``luq_quantize`` makes for the
    original tensors with the same keys — the bit-parity contract with
    the pallas-backend vmap path).  Per-tensor alphas and uniform bits
    are computed on the unpadded operands; rows are zero-padded to a
    sublane multiple and both operands to one shared lane-aligned column
    count (zeros quantize to zero and contribute nothing to either Gram).
    """
    interpret = _interpret_default() if interpret is None else interpret
    t = x.shape[0]
    assert g.shape[0] == t, (x.shape, g.shape)
    if t > GHOST_NORM_MAX_T:
        # Gram scratch would not fit VMEM on a real TPU — unfused
        # composition, same keys/draws -> bit-identical result
        xq = luq_quantize(x, key_x).astype(jnp.float32)
        gq = luq_quantize(g, key_g).astype(jnp.float32)
        return jnp.vdot(xq @ xq.T, gq @ gq.T)
    ux = luq_uniform(key_x, x.shape)
    ug = luq_uniform(key_g, g.shape)
    alpha_x = jnp.max(jnp.abs(x.astype(jnp.float32))).reshape(1, 1)
    alpha_g = jnp.max(jnp.abs(g.astype(jnp.float32))).reshape(1, 1)
    d = max(x.shape[1], g.shape[1])
    d = d + ((-d) % block_d)
    pt = (-t) % 8

    def pad(a):
        return jnp.pad(a.astype(jnp.float32),
                       ((0, pt), (0, d - a.shape[1])))

    out = ghost_norm_gram(pad(x), pad(ux), pad(g), pad(ug), alpha_x,
                          alpha_g, block_d=block_d, interpret=interpret)
    return out[0, 0]


@functools.partial(jax.jit, static_argnames=("fmt", "block_rows",
                                             "interpret"))
def kv_quant_rows(x: jax.Array, fmt: str, block_rows: int = 128,
                  interpret=None):
    """Fused KV-row quantization of ``(..., head_dim)`` K/V rows.

    Returns ``(codes, scales)`` exactly like the ref
    ``repro.quant.kv_cache.kv_quant``: codes ``(..., code_dim)`` (int8, or
    nibble-packed uint8 for luq_fp4) and per-row bf16 scales ``(...,)``.
    The kernel computes the per-row amax, the bf16-rounded scale, and the
    codes in one VMEM pass per row block; rows are padded to a
    ``block_rows`` multiple and head_dim to a lane multiple (zero columns
    never raise a nonzero row's amax, and all-zero pad rows get scale 0).
    Deterministic, so it is bit-compatible with the ref impl by
    construction — both encode with the shared elementwise math in
    ``repro.quant.kv_cache``.
    """
    interpret = _interpret_default() if interpret is None else interpret
    shape = x.shape
    hd = shape[-1]
    _, code_dim = kvc.code_spec(fmt, hd)
    rows = x.reshape(-1, hd).astype(jnp.float32)
    r = rows.shape[0]
    pr = (-r) % block_rows
    pd = (-hd) % 128
    if pr or pd:
        rows = jnp.pad(rows, ((0, pr), (0, pd)))
    codes, scales = kv_rowquant_2d(rows, fmt, block_rows=block_rows,
                                   interpret=interpret)
    codes = codes[:r, :hd]
    scales = scales[:r, 0].astype(kvc.SCALE_DTYPE)
    if fmt == "luq_fp4":
        codes = kvc.fp4_pack(codes.astype(jnp.uint8))
    return (codes.reshape(shape[:-1] + (code_dim,)),
            scales.reshape(shape[:-1]))


@functools.partial(jax.jit, static_argnames=("fmt", "n_kv", "scale",
                                             "interpret"))
def decode_attn_fused(q: jax.Array, k_codes: jax.Array, v_codes: jax.Array,
                      k_scale: jax.Array, v_scale: jax.Array, pos, *,
                      fmt: str, n_kv: int, scale: float, interpret=None):
    """Fused decode attention over a quantized slot-pool cache.

    Same signature/semantics as ``repro.quant.kv_cache.ref_decode_attn``
    for the quantized formats: ``q`` (B, H, hd), stored code rows
    (B, KV, S, code_dim) with (B, KV, S) bf16 scales, ``pos`` scalar or
    (B,) per-slot positions.  One VMEM pass per (slot, kv-head): decode,
    scale-fold, mask, softmax, PV (``repro.kernels.decode_attn``).
    Padding: q-head groups to a sublane multiple, head_dim (packed dim
    for luq_fp4) to a lane multiple, S to a sublane multiple — padded
    rows carry zero codes/scales and masked positions, contributing
    exactly zero.
    """
    interpret = _interpret_default() if interpret is None else interpret
    b, hp, hd = q.shape
    g = hp // n_kv
    s = k_codes.shape[2]
    dp = k_codes.shape[3]
    if fmt == "luq_fp4":
        pad_dp = (-dp) % 64          # packed dim -> 128 decoded lanes
        hd_padded = 2 * (dp + pad_dp)
    else:
        pad_dp = (-dp) % 128
        hd_padded = dp + pad_dp
    pg, ps = (-g) % 8, (-s) % 8
    qg = q.reshape(b, n_kv, g, hd).astype(jnp.float32)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, pg), (0, hd_padded - hd)))
    kc = jnp.pad(k_codes, ((0, 0), (0, 0), (0, ps), (0, pad_dp)))
    vc = jnp.pad(v_codes, ((0, 0), (0, 0), (0, ps), (0, pad_dp)))
    ks = jnp.pad(k_scale.astype(jnp.float32), ((0, 0), (0, 0), (0, ps)))
    vs = jnp.pad(v_scale.astype(jnp.float32), ((0, 0), (0, 0), (0, ps)))
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,)).reshape(b, 1)
    ctx = decode_attn_call(qg, kc, ks, vc, vs, pos_b, fmt=fmt, scale=scale,
                           interpret=interpret)
    return ctx[:, :, :g, :hd].reshape(b, hp, hd)


@functools.partial(jax.jit, static_argnames=("clip_norm", "block_d",
                                             "interpret"))
def clip_and_sum(grads: jax.Array, clip_norm: float, block_d: int = 512,
                 interpret=None):
    """Fused DP per-example clip + batch sum.

    ``grads``: (B, D) per-example gradient rows, any float dtype, any B >= 1
    and D >= 1 (D is zero-padded to a ``block_d`` multiple internally —
    zero columns change neither the row norms nor the sum, and the padding
    is stripped before returning).

    Returns ``(clipped_sum, norms)`` matching ``ref.per_sample_clip_ref``:
    ``clipped_sum`` (D,) f32 = sum_b min(1, C/||g_b||) * g[b], and ``norms``
    (B,) f32 per-example l2 norms (the clip-fraction / grad-norm
    diagnostics of paper Fig. 1c are computed from these).
    """
    interpret = _interpret_default() if interpret is None else interpret
    b, d = grads.shape
    pd = (-d) % block_d
    if pd:
        grads = jnp.pad(grads, ((0, 0), (0, pd)))
    out, norms = per_sample_clip(grads, clip_norm, block_d=block_d,
                                 interpret=interpret)
    return out[:d], norms
