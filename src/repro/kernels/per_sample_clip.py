"""Pallas TPU kernel: fused per-example clip + batch reduction.

The DP-SGD inner loop reduces, for each example b, its gradient row to an
l2 norm, rescales to norm <= C, and sums over the batch:

    out[d] = sum_b min(1, C / ||g_b||) * g[b, d]

Doing this as three XLA ops re-reads the (B, D) gradient matrix from HBM
twice.  The fused kernel streams each (B, bd) column block once:

  pass 1 (grid dim 0): accumulate per-example partial square sums in a VMEM
     scratch (B, 1);
  pass 2 (grid dim 0 again, second grid axis selects the phase): apply
     min(1, C/norm) and accumulate the weighted column sums.

Implemented as a 2-phase grid: phase 0 only touches the square-sum scratch;
phase 1 re-reads the block (still VMEM-resident for small B*bd) and writes
the clipped sum.  Norms are emitted for the clip-fraction diagnostics
(paper Fig. 1c).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _clip_kernel(g_ref, o_ref, norms_ref, sq_ref, *, n_cols, clip_norm):
    phase = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((phase == 0) & (j == 0))
    def _():
        sq_ref[...] = jnp.zeros_like(sq_ref)

    g = g_ref[...].astype(jnp.float32)

    @pl.when(phase == 0)
    def _():
        sq_ref[...] += jnp.sum(g * g, axis=1, keepdims=True)

    @pl.when(phase == 1)
    def _():
        norms = jnp.sqrt(sq_ref[...])
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norms, 1e-12))
        o_ref[...] = jnp.sum(g * scale, axis=0, keepdims=True)
        @pl.when(j == n_cols - 1)
        def _():
            norms_ref[...] = norms

    # keep outputs defined in phase 0 as well (same blocks revisited)
    @pl.when((phase == 0) & (j == 0))
    def _():
        norms_ref[...] = jnp.zeros_like(norms_ref)

    @pl.when(phase == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)


def per_sample_clip(grads: jax.Array, clip_norm: float, block_d: int = 512,
                    interpret: bool = False):
    """grads: (B, D) per-example gradient rows.

    Returns (clipped_sum (D,), norms (B,)).  D % block_d == 0 required
    (pad upstream); B must fit a VMEM tile (true for microbatch sizes).
    """
    b, d = grads.shape
    bd = min(block_d, d)
    assert d % bd == 0, (d, bd)
    n_cols = d // bd
    out, norms = pl.pallas_call(
        functools.partial(_clip_kernel, n_cols=n_cols, clip_norm=clip_norm),
        grid=(2, n_cols),
        in_specs=[pl.BlockSpec((b, bd), lambda p, j: (0, j))],
        out_specs=[pl.BlockSpec((1, bd), lambda p, j: (0, j)),
                   pl.BlockSpec((b, 1), lambda p, j: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, d), jnp.float32),
                   jax.ShapeDtypeStruct((b, 1), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((b, 1), jnp.float32)],
        interpret=interpret,
    )(grads)
    return out[0], norms[:, 0]
