"""Pallas TPU kernel: fused LUQ-FP4 quantize-both-operands matmul.

The TPU-native adaptation of the paper's FP4 GEMM (DESIGN.md §3): instead of
a separate fake-quant pass + GEMM (two HBM round trips on GPU), each (bm, bk)
A-tile and (bk, bn) B-tile is quantized *in VMEM* right before feeding the
MXU, accumulating fp32 in a VMEM scratch across the k grid dimension.
Quantization therefore adds zero HBM traffic; on FP4 hardware the dequant
multiply folds into the MXU pipeline.

Tile defaults (128, 128, 512): A-tile 256 KiB + B-tile 256 KiB + acc 64 KiB
(+ random tiles) fits VMEM with double buffering; all dims are 128-multiples
(MXU-aligned).

Random bits: two uniform tensors, tiled like A and B.  Per-tensor scales are
precomputed (single fused max pass) and passed as scalars.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.quant.formats import LUQ_EXP_LEVELS


def _luq(x, u, alpha):
    safe_alpha = jnp.where(alpha > 0, alpha, 1.0)
    sign = jnp.sign(x)
    y = jnp.abs(x) / safe_alpha
    min_level = 2.0 ** (-(LUQ_EXP_LEVELS - 1))
    under = jnp.where(u < y / min_level, min_level, 0.0)
    ylog = jnp.log2(jnp.maximum(y, min_level))
    k = jnp.clip(jnp.floor(ylog), -(LUQ_EXP_LEVELS - 1), 0.0)
    low = jnp.exp2(k)
    high = jnp.minimum(jnp.exp2(k + 1.0), 1.0)
    rounded = jnp.where(u < (y - low) / jnp.maximum(high - low, 1e-30),
                        high, low)
    q = jnp.where(y < min_level, under, rounded)
    return jnp.where(alpha > 0, sign * q * safe_alpha, 0.0)


def _qmm_kernel(a_ref, b_ref, ua_ref, ub_ref, aa_ref, ab_ref, o_ref,
                acc_ref, *, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    aq = _luq(a, ua_ref[...], aa_ref[0, 0])
    bq = _luq(b, ub_ref[...], ab_ref[0, 0])
    acc_ref[...] += jnp.dot(aq, bq, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def quant_matmul(a: jax.Array, b: jax.Array, ua: jax.Array, ub: jax.Array,
                 alpha_a: jax.Array, alpha_b: jax.Array,
                 block=(128, 128, 512), interpret: bool = False) -> jax.Array:
    """(M, K) x (K, N) with in-tile LUQ quantization of both operands."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm, bn, bk = (min(block[0], m), min(block[1], n), min(block[2], k))
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, block)
    k_steps = k // bk
    aa = alpha_a.reshape(1, 1).astype(jnp.float32)
    ab = alpha_b.reshape(1, 1).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(_qmm_kernel, k_steps=k_steps),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b, ua, ub, aa, ab)
