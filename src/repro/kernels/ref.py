"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.formats import LUQ_EXP_LEVELS


def luq_quant_ref(x: jax.Array, u: jax.Array, alpha) -> jax.Array:
    """LUQ-FP4 stochastic quantizer given uniform random bits ``u`` and a
    precomputed per-tensor scale ``alpha`` (see kernels/luq_quant.py)."""
    xf = x.astype(jnp.float32)
    safe_alpha = jnp.where(alpha > 0, alpha, 1.0)
    sign = jnp.sign(xf)
    y = jnp.abs(xf) / safe_alpha
    min_level = 2.0 ** (-(LUQ_EXP_LEVELS - 1))
    p_under = y / min_level
    under = jnp.where(u < p_under, min_level, 0.0)
    ylog = jnp.log2(jnp.maximum(y, min_level))
    k = jnp.clip(jnp.floor(ylog), -(LUQ_EXP_LEVELS - 1), 0.0)
    low = jnp.exp2(k)
    high = jnp.minimum(jnp.exp2(k + 1.0), 1.0)
    p_up = (y - low) / jnp.maximum(high - low, 1e-30)
    rounded = jnp.where(u < p_up, high, low)
    q = jnp.where(y < min_level, under, rounded)
    out = sign * q * safe_alpha
    return jnp.where(alpha > 0, out, 0.0).astype(x.dtype)


def quant_matmul_ref(a: jax.Array, b: jax.Array, ua: jax.Array,
                     ub: jax.Array, alpha_a, alpha_b) -> jax.Array:
    """Fused LUQ-quantize-both-operands matmul oracle (fp32 accumulate)."""
    aq = luq_quant_ref(a, ua, alpha_a).astype(jnp.float32)
    bq = luq_quant_ref(b, ub, alpha_b).astype(jnp.float32)
    return aq @ bq


def per_sample_clip_ref(grads: jax.Array, clip_norm: float) -> jax.Array:
    """Per-row clip: grads (B, D) -> sum_b clip_C(grads[b]).  Also returns
    per-row norms."""
    norms = jnp.sqrt(jnp.sum(jnp.square(grads.astype(jnp.float32)), axis=1))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norms, 1e-12))
    clipped = grads.astype(jnp.float32) * scale[:, None]
    return clipped.sum(axis=0), norms
