import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost/collective analyses.

THE two lines above must run before any jax import (jax locks the device
count at first init); that's why this module sets XLA_FLAGS at the very top
and must be the process entry point:

    PYTHONPATH=src python -m repro.launch.dryrun --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k

Outputs one JSON per cell under --out (default results/dryrun/).
"""
import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from pathlib import Path  # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.config import (DPConfig, OptimConfig, QuantConfig, RunConfig,
                          SHAPES)  # noqa: E402
from repro.configs import ASSIGNED_ARCHS, get_config  # noqa: E402
from repro.launch import hlo_analysis, roofline  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_serve_setup, build_train_setup  # noqa: E402
from repro.models.registry import build_model  # noqa: E402


def cell_skip_reason(cfg, shape) -> str:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("SKIP(full-attention): 500k dense-KV decode is assigned only "
                "to sub-quadratic (ssm/hybrid) archs")
    return ""


def _mem_dict(ma) -> dict:
    return {k: getattr(ma, k) for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes")}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             fmt: str = "luq_fp4", extra_tag: str = "",
             overrides: dict = None, dp_overrides: dict = None) -> dict:
    import dataclasses as _dc
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "kind": shape.kind, "tag": extra_tag}
    reason = cell_skip_reason(cfg, shape)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    quant = QuantConfig(fmt=fmt)
    model = build_model(cfg, quant)
    dp_kwargs = dict(enabled=True, microbatch_size=1,
                     microbatch_mode=("single" if cfg.family == "moe_lm"
                                      else "data_parallel"),
                     grad_accum_dtype=("bfloat16" if cfg.family == "moe_lm"
                                       else "float32"))
    if dp_overrides:
        dp_kwargs.update(dp_overrides)
    run = RunConfig(
        model=cfg, quant=quant,
        dp=DPConfig(**dp_kwargs),
        optim=OptimConfig(name="sgd", lr=0.5),
        global_batch=shape.global_batch, seq_len=shape.seq_len)

    t0 = time.time()
    if shape.kind == "train":
        setup = build_train_setup(model, run, mesh)
        jitted = jax.jit(setup.step_fn, in_shardings=setup.in_shardings,
                         out_shardings=setup.out_shardings)
        lowered = jitted.lower(*setup.abstract_args)
    elif shape.kind == "prefill":
        setup = build_serve_setup(model, run, mesh,
                                  shape.global_batch, shape.seq_len)
        jitted = jax.jit(setup.prefill_fn,
                         in_shardings=setup.prefill_in_shardings)
        lowered = jitted.lower(*setup.prefill_abstract)
    else:  # decode
        setup = build_serve_setup(model, run, mesh,
                                  shape.global_batch, shape.seq_len)
        jitted = jax.jit(setup.decode_fn,
                         in_shardings=setup.decode_in_shardings)
        lowered = jitted.lower(*setup.decode_abstract)
    rec["lower_s"] = round(time.time() - t0, 2)

    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    ma = compiled.memory_analysis()
    print(f"[{arch} x {shape_name} x {rec['mesh']}] memory_analysis:", ma)
    ca = dict(compiled.cost_analysis())
    print(f"[{arch} x {shape_name} x {rec['mesh']}] xla cost_analysis "
          f"(per-iteration, loops counted once): "
          f"flops={ca.get('flops', 0):.3e} "
          f"bytes={ca.get('bytes accessed', 0):.3e}")
    hlo = compiled.as_text()
    analysis = hlo_analysis.analyze(hlo)

    n_dev = mesh.devices.size
    abstract_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    mf = roofline.model_flops(cfg, abstract_params, shape.kind,
                              shape.global_batch, shape.seq_len, n_dev)
    terms = roofline.derive(ca, hlo, model_flops_per_device=mf,
                            hlo_analysis=analysis)

    rec.update({
        "status": "ok",
        "memory": _mem_dict(ma),
        "xla_cost": {k: float(v) for k, v in ca.items()
                     if isinstance(v, (int, float))},
        "collectives": analysis["collectives"],
        "hlo_warnings": analysis["warnings"],
        "roofline": terms.as_dict(),
        "n_params": roofline.count_params(abstract_params),
        "n_active_params": roofline.active_params(cfg, abstract_params),
        "n_devices": n_dev,
    })
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (the 10 assigned)")
    ap.add_argument("--shape", default="all",
                    help="train_4k|prefill_32k|decode_32k|long_500k|all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--fmt", default="luq_fp4")
    ap.add_argument("--tag", default="", help="variant tag for perf runs")
    ap.add_argument("--out", default="results/dryrun")
    # perf-variant overrides (hillclimb levers)
    ap.add_argument("--microbatch-size", type=int, default=None)
    ap.add_argument("--partial-accum", action="store_true")
    ap.add_argument("--ssm-chunk", type=int, default=None)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--attn-chunk-q", type=int, default=None)
    args = ap.parse_args()

    overrides = {}
    if args.ssm_chunk is not None:
        overrides["ssm_chunk"] = args.ssm_chunk
    if args.capacity_factor is not None:
        overrides["moe_capacity_factor"] = args.capacity_factor
    if args.attn_chunk_q is not None:
        overrides["attn_chunk_q"] = args.attn_chunk_q
    dp_overrides = {}
    if args.microbatch_size is not None:
        dp_overrides["microbatch_size"] = args.microbatch_size
    if args.partial_accum:
        dp_overrides["partial_accum"] = True

    archs = ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_tag = "multi" if mp else "single"
                name = f"{arch}__{shape}__{mesh_tag}"
                if args.tag:
                    name += f"__{args.tag}"
                path = outdir / f"{name}.json"
                try:
                    rec = run_cell(arch, shape, mp, fmt=args.fmt,
                                   extra_tag=args.tag, overrides=overrides,
                                   dp_overrides=dp_overrides)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": str(e),
                           "traceback": traceback.format_exc()}
                    failures += 1
                    print(f"[{name}] ERROR: {e}")
                path.write_text(json.dumps(rec, indent=2, default=str))
                status = rec.get("status")
                if status == "ok":
                    r = rec["roofline"]
                    print(f"[{name}] OK compute={r['compute_s']:.3e}s "
                          f"memory={r['memory_s']:.3e}s "
                          f"collective={r['collective_s']:.3e}s "
                          f"dominant={r['dominant']}")
                elif status == "skipped":
                    print(f"[{name}] {rec['reason']}")
    print("dry-run complete; failures:", failures)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
