"""Trip-count-aware cost analysis of optimized HLO text.

``compiled.cost_analysis()`` counts ``while`` bodies ONCE — a transformer
scanned over layers inside a scan over DP microbatches under-reports FLOPs by
orders of magnitude, and collectives inside loops are likewise missed by a
naive text grep.  This module re-derives

    flops            dot/conv exact; elementwise approximate (1/elem)
    bytes            per-instruction operand+result bytes at fusion
                     boundaries (post-fusion ~ HBM traffic)
    collectives      result bytes per op kind, multiplied by loop trips

by walking the computation graph with while-loop trip counts extracted from
the loop condition (canonical scan lowering: ``compare(iv, constant(N)),
direction=LT``).  Conditionals take the max across branches.  Unknown trip
counts fall back to 1 and are surfaced in ``warnings``.

Validated against analytic FLOP counts in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s8": 1, "u8": 1, "pred": 1,
    "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([0-9,]*)\]")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "cosine", "sine", "floor", "ceil", "round-nearest-afz", "sign",
    "expm1", "log-plus-one", "atan2", "remainder", "logistic",
    "exponential-minus-one", "erf", "cbrt",
}

_SKIP_BYTES = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "get-dimension-size",
    "opt-barrier", "custom-call", "rng-bit-generator", "bitcast-convert",
    "reshape",   # post-layout-assignment reshapes are bitcasts
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_elems_bytes(shape_str: str) -> Tuple[float, float]:
    elems = 0.0
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str              # raw result shape string (may be a tuple)
    op: str
    operands: List[str]
    raw: str


# result shape may be a tuple containing /*index=N*/ comments; the op name is
# the first whitespace-preceded word directly followed by '('.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")


def _parse_operands(rest: str) -> List[str]:
    ops = []
    depth = 0
    for m in re.finditer(r"%([\w.\-]+)|[()]", rest):
        tok = m.group(0)
        if tok == "(":
            depth += 1
        elif tok == ")":
            if depth == 0:
                break
            depth -= 1
        else:
            ops.append(m.group(1))
    return ops


def parse_module(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    current: Optional[str] = None
    for line in text.splitlines():
        stripped = line.strip()
        header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{", stripped)
        if header and not stripped.startswith("//"):
            current = header.group(1)
            comps[current] = []
            continue
        if stripped.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape, op, rest = m.groups()
        comps[current].append(Instr(name, shape.strip(), op,
                                    _parse_operands(rest), line))
    return comps


def _attr(raw: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-$]+)", raw)
    return m.group(1) if m else None


def _dims_attr(raw: str, key: str) -> List[int]:
    m = re.search(key + r"=\{([0-9,]*)\}", raw)
    if not m or not m.group(1):
        return []
    return [int(x) for x in m.group(1).split(",")]


def _result_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(x) for x in m.group(2).split(",") if x]


class Analyzer:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self.symtab: Dict[str, Dict[str, str]] = {}
        for cname, instrs in self.comps.items():
            tab = {}
            for ins in instrs:
                tab[ins.name] = ins.shape
            self.symtab[cname] = tab
        # parameter shapes from headers
        for line in text.splitlines():
            h = re.match(
                r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*{",
                line.strip())
            if not h:
                continue
        self._memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}
        self.warnings: List[str] = []
        # parameter shapes appear as explicit parameter instructions; fine.

    # ------------------------------------------------------------------ #
    def _operand_shape(self, comp: str, name: str) -> str:
        return self.symtab.get(comp, {}).get(name, "")

    def _dot_flops(self, comp: str, ins: Instr) -> float:
        out_elems, _ = _shape_elems_bytes(ins.shape)
        lhs_shape = self._operand_shape(comp, ins.operands[0])
        lhs_dims = _result_dims(lhs_shape)
        contr = _dims_attr(ins.raw, "lhs_contracting_dims")
        k = 1
        for c in contr:
            if c < len(lhs_dims):
                k *= lhs_dims[c]
        return 2.0 * out_elems * k

    def _conv_flops(self, comp: str, ins: Instr) -> float:
        out_elems, _ = _shape_elems_bytes(ins.shape)
        ker_shape = _result_dims(self._operand_shape(comp, ins.operands[1]))
        m = re.search(r"dim_labels=(\S+?)_(\S+?)->(\S+?)[,}\s]", ins.raw)
        groups = int(_attr(ins.raw, "feature_group_count") or 1)
        if not ker_shape or not m:
            return 2.0 * out_elems  # degraded estimate
        klabels = m.group(2)
        per_out = 1
        for lab, dim in zip(klabels, ker_shape):
            if lab == "o":
                continue
            per_out *= dim
        return 2.0 * out_elems * per_out / max(groups, 1)

    def _trip_count(self, cond_comp: str) -> float:
        instrs = self.comps.get(cond_comp, [])
        consts = []
        for ins in instrs:
            m = re.search(r"constant\((\d+)\)", ins.raw)
            if m and ins.shape.startswith(("s32", "u32", "s64", "u64")):
                consts.append(int(m.group(1)))
        if consts:
            return float(max(consts))
        self.warnings.append(f"unknown trip count for {cond_comp}; using 1")
        return 1.0

    # ------------------------------------------------------------------ #
    def comp_cost(self, comp: str, count_bytes: bool = True
                  ) -> Tuple[float, float, Dict[str, float]]:
        key = (comp, count_bytes)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = (0.0, 0.0, {})  # cycle guard
        flops = 0.0
        byts = 0.0
        colls: Dict[str, float] = {}
        for ins in self.comps.get(comp, []):
            f, b, c = self.instr_cost(comp, ins, count_bytes)
            flops += f
            byts += b
            for k, v in c.items():
                colls[k] = colls.get(k, 0.0) + v
        self._memo[key] = (flops, byts, colls)
        return self._memo[key]

    def instr_cost(self, comp: str, ins: Instr, count_bytes: bool = True):
        flops = 0.0
        byts = 0.0
        colls: Dict[str, float] = {}
        op = ins.op
        base = op.replace("-start", "")
        if base in _COLLECTIVES and not op.endswith("-done"):
            _, b = _shape_elems_bytes(ins.shape)
            colls[base] = colls.get(base, 0.0) + b

        if op == "while":
            body = _attr(ins.raw, "body")
            cond = _attr(ins.raw, "condition")
            trips = self._trip_count(cond.lstrip("%")) if cond else 1.0
            bf, bb, bc = (self.comp_cost(body.lstrip("%"), count_bytes)
                          if body else (0, 0, {}))
            flops += trips * bf
            byts += trips * bb
            for k, v in bc.items():
                colls[k] = colls.get(k, 0.0) + trips * v
            return flops, byts, colls

        if op == "conditional":
            branches = re.findall(r"branch_computations=\{([^}]*)\}", ins.raw)
            names = []
            if branches:
                names = [b.strip().lstrip("%") for b in branches[0].split(",")]
            else:
                tc = _attr(ins.raw, "true_computation")
                fc = _attr(ins.raw, "false_computation")
                names = [x.lstrip("%") for x in (tc, fc) if x]
            best = (0.0, 0.0, {})
            for nm in names:
                c = self.comp_cost(nm, count_bytes)
                if c[0] + c[1] > best[0] + best[1]:
                    best = c
            flops += best[0]
            byts += best[1]
            for k, v in best[2].items():
                colls[k] = colls.get(k, 0.0) + v
            if count_bytes:
                byts += self._io_bytes(comp, ins)
            return flops, byts, colls

        if op in ("fusion", "call", "async-start"):
            called = _attr(ins.raw, "calls") or _attr(ins.raw, "to_apply")
            if called:
                # descend for flops/collectives only — fused interior ops
                # stay in VMEM, HBM traffic is the fusion boundary I/O
                cf, _, cc = self.comp_cost(called.lstrip("%"),
                                           count_bytes=False)
                flops += cf
                for k, v in cc.items():
                    colls[k] = colls.get(k, 0.0) + v
            if count_bytes:
                byts += self._fusion_io_bytes(comp, ins,
                                              called.lstrip("%")
                                              if called else None)
            return flops, byts, colls

        if op == "dot":
            flops += self._dot_flops(comp, ins)
            if count_bytes:
                byts += self._io_bytes(comp, ins)
            return flops, byts, colls

        if op == "convolution":
            flops += self._conv_flops(comp, ins)
            if count_bytes:
                byts += self._io_bytes(comp, ins)
            return flops, byts, colls

        if op in _ELEMENTWISE:
            elems, _ = _shape_elems_bytes(ins.shape)
            flops += elems
            return flops, byts, colls  # fused ops: bytes counted at fusion

        if op in ("reduce", "reduce-window", "select-and-scatter"):
            elems, _ = _shape_elems_bytes(
                self._operand_shape(comp, ins.operands[0]) or ins.shape)
            flops += elems
            if count_bytes:
                byts += self._io_bytes(comp, ins)
            return flops, byts, colls

        if op == "scatter":
            # in-place: touch the updates + indices, not the whole buffer
            upd = (self._operand_shape(comp, ins.operands[2])
                   if len(ins.operands) > 2 else "")
            ue, ub = _shape_elems_bytes(upd)
            flops += ue
            if count_bytes:
                _, ib = _shape_elems_bytes(
                    self._operand_shape(comp, ins.operands[1])
                    if len(ins.operands) > 1 else "")
                byts += 2.0 * ub + ib
            return flops, byts, colls

        if op == "gather":
            if count_bytes:
                _, ob = _shape_elems_bytes(ins.shape)
                _, ib = _shape_elems_bytes(
                    self._operand_shape(comp, ins.operands[1])
                    if len(ins.operands) > 1 else "")
                byts += 2.0 * ob + ib
            return flops, byts, colls

        if op == "dynamic-slice":
            # reads only the slice (XLA lowers in-place inside loops):
            # bytes = read slice + write result
            if count_bytes:
                _, ob = _shape_elems_bytes(ins.shape)
                byts += 2.0 * ob
            return flops, byts, colls

        if op == "dynamic-update-slice":
            # in-place update: bytes = read update + write region
            if count_bytes:
                upd = (self._operand_shape(comp, ins.operands[1])
                       if len(ins.operands) > 1 else "")
                _, ub = _shape_elems_bytes(upd)
                if ub == 0.0:
                    _, ub = _shape_elems_bytes(ins.shape)
                    ub *= 0.0  # unknown update extent; don't charge the buffer
                byts += 2.0 * ub
            return flops, byts, colls

        if count_bytes and op not in _SKIP_BYTES and not op.endswith("-done"):
            byts += self._io_bytes(comp, ins)
        return flops, byts, colls

    def _fusion_io_bytes(self, comp: str, ins: Instr,
                         called: str) -> float:
        """Fusion boundary I/O, with sliced-parameter correction: an operand
        whose only in-fusion uses are dynamic-slice / gather / (as-buffer)
        dynamic-update-slice contributes the slice bytes, not the whole
        buffer (layer-stack reads inside scans would otherwise count the
        entire stack every iteration)."""
        _, out_b = _shape_elems_bytes(ins.shape)
        total = out_b
        body = self.comps.get(called or "", [])
        params = [i for i in body if i.op == "parameter"]
        # positional parameter(k) -> operand k
        pname_to_idx = {}
        for i in body:
            if i.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", i.raw)
                if m:
                    pname_to_idx[i.name] = int(m.group(1))
        sliced_bytes: Dict[int, float] = {}
        full: Dict[int, bool] = {}
        for i in body:
            for oi, oname in enumerate(i.operands):
                if oname not in pname_to_idx:
                    continue
                idx = pname_to_idx[oname]
                if i.op == "dynamic-slice" and oi == 0:
                    _, b = _shape_elems_bytes(i.shape)
                    sliced_bytes[idx] = sliced_bytes.get(idx, 0.0) + b
                elif i.op == "gather" and oi == 0:
                    _, b = _shape_elems_bytes(i.shape)
                    sliced_bytes[idx] = sliced_bytes.get(idx, 0.0) + b
                elif i.op == "dynamic-update-slice" and oi == 0:
                    upd = self.symtab.get(called, {}).get(
                        i.operands[1], "") if len(i.operands) > 1 else ""
                    _, b = _shape_elems_bytes(upd)
                    sliced_bytes[idx] = sliced_bytes.get(idx, 0.0) + b
                else:
                    full[idx] = True
        for k, oname in enumerate(ins.operands):
            _, b = _shape_elems_bytes(self._operand_shape(comp, oname))
            if k in sliced_bytes and not full.get(k, False):
                total += min(b, sliced_bytes[k])
            else:
                total += b
        return total

    def _io_bytes(self, comp: str, ins: Instr) -> float:
        _, out_b = _shape_elems_bytes(ins.shape)
        in_b = 0.0
        for o in ins.operands:
            _, b = _shape_elems_bytes(self._operand_shape(comp, o))
            in_b += b
        return in_b + out_b

    # ------------------------------------------------------------------ #
    def entry(self) -> Optional[str]:
        # the scheduled entry computation is conventionally named main.*
        for name in self.comps:
            if name.startswith("main"):
                return name
        # fallback: the largest computation
        return max(self.comps, key=lambda c: len(self.comps[c]), default=None)


def analyze(text: str) -> dict:
    az = Analyzer(text)
    entry = az.entry()
    flops, byts, colls = az.comp_cost(entry) if entry else (0.0, 0.0, {})
    wire = sum(v * _WIRE_FACTOR[k] for k, v in colls.items())
    return {
        "flops": flops,
        "bytes": byts,
        "collectives": colls,
        "collective_bytes": sum(colls.values()),
        "collective_wire_bytes": wire,
        "warnings": az.warnings,
        "entry": entry,
    }
