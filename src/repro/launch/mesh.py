"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls this.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (CPU tests / small runs)."""
    n = len(jax.devices())
    mp = model_parallel if n % model_parallel == 0 else 1
    return jax.make_mesh((n // mp, mp), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
