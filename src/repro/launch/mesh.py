"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls this.

``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg of
``jax.make_mesh``) only exist in newer JAX releases; ``make_compat_mesh``
papers over the difference so every mesh in the repo builds on any
supported JAX.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto
    AxisType = None


def make_compat_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_compat_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (CPU tests / small runs)."""
    n = len(jax.devices())
    mp = model_parallel if n % model_parallel == 0 else 1
    return make_compat_mesh((n // mp, mp), ("data", "model"))
