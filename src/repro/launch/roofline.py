"""Roofline-term derivation from compiled XLA artifacts (no real hardware).

Per (arch x shape x mesh) cell:
    compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_wire_bytes_per_device / link_bw

Sources: ``compiled.cost_analysis()`` reports per-device (per-partition)
FLOPs and bytes; collective bytes are parsed from the post-SPMD optimized
HLO (``compiled.as_text()``) since cost_analysis excludes them.  Wire-byte
factors use ring-algorithm costs: all-reduce moves ~2x the buffer over the
slowest link, all-gather / reduce-scatter ~1x, all-to-all ~1x,
collective-permute 1x.

Hardware constants (v5e-class, from the assignment):
    197 TFLOP/s bf16 per chip | 819 GB/s HBM | ~50 GB/s/link ICI.

MODEL_FLOPS sanity ratio: 6*N*D (train) / 2*N*D (prefill) / 2*N*B (decode),
with N_active for MoE — the fraction of compiled compute that is "useful"
(catches remat recompute, dispatch overheads, padded heads/vocab waste).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes / s
LINK_BW = 50e9               # bytes / s / link

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "s4": 0.5, "u4": 0.5,
}

_COLL_RE = re.compile(
    r"(\(?[a-z0-9,\[\]{}() ]*?\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(shape_str: str) -> float:
    """Sum bytes over every 'dtype[dims]' group in a (possibly tuple) shape."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, float]:
    """Per-op-kind result bytes (per device) from optimized HLO text."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "-done" in line.split("=")[-1][:60]:
            continue  # async *-done repeats the shape of the *-start
        m = re.search(
            r"=\s+(\(?.*?\)?)\s+"
            r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?\(", line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2).lower()
        b = _shape_bytes(shape_str)
        out[kind] = out.get(kind, 0.0) + b
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float
    bytes_accessed: float
    collective_bytes: float          # result bytes, per device
    collective_wire_bytes: float     # ring-cost wire bytes
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_per_device: Optional[float] = None
    useful_ratio: Optional[float] = None

    def as_dict(self):
        return dataclasses.asdict(self)


def derive(cost_analysis: dict, hlo_text: str,
           model_flops_per_device: Optional[float] = None,
           hlo_analysis: Optional[dict] = None) -> RooflineTerms:
    """Prefer the trip-count-aware analyzer (repro.launch.hlo_analysis);
    XLA's cost_analysis counts while bodies once and is kept only as a
    cross-reference."""
    if hlo_analysis is None:
        from repro.launch import hlo_analysis as ha
        hlo_analysis = ha.analyze(hlo_text)
    flops = float(hlo_analysis["flops"])
    bytes_accessed = float(hlo_analysis["bytes"])
    colls = hlo_analysis["collectives"]
    coll_bytes = sum(colls.values())
    wire = float(hlo_analysis["collective_wire_bytes"])
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    coll_s = wire / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    ratio = (model_flops_per_device / flops
             if model_flops_per_device and flops else None)
    return RooflineTerms(
        flops=flops, bytes_accessed=bytes_accessed,
        collective_bytes=coll_bytes, collective_wire_bytes=wire,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        dominant=dominant, model_flops_per_device=model_flops_per_device,
        useful_ratio=ratio)


# --------------------------------------------------------------------------- #
# MODEL_FLOPS estimation
# --------------------------------------------------------------------------- #
def count_params(abstract_params) -> int:
    import jax
    return int(sum(x.size for x in jax.tree_util.tree_leaves(abstract_params)))


def active_params(cfg, abstract_params) -> int:
    """N_active: for MoE, experts count at top_k / n_experts utilization."""
    import jax
    total = 0
    flat = jax.tree_util.tree_leaves_with_path(abstract_params)
    for path, leaf in flat:
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        frac = 1.0
        if cfg.family == "moe_lm" and any(
                str(k).startswith("e_") for k in keys):
            frac = cfg.top_k / max(cfg.n_experts, 1)
        total += leaf.size * frac
    return int(total)


def _attention_flops(cfg, kind: str, B: int, S: int) -> float:
    """Quadratic attention term missing from 6*N*D (PaLM-appendix style).

    fwd = 4 * B * S^2 * (H*hd) / 2 (causal); train multiplies by 4
    (fwd + 2x bwd + remat re-fwd); decode reads S keys for 1 query."""
    H = getattr(cfg, "padded_heads", 0) or 0
    hd = cfg.head_dim or 0
    if H == 0 or hd == 0:
        return 0.0
    if cfg.family == "hybrid":
        # only 1-in-3 layers attend, over a bounded window
        L_attn = cfg.n_layers // 3
        span = min(cfg.attn_window, S)
        per_layer_fwd = 4.0 * B * S * span * H * hd / 2.0
    elif cfg.family == "encdec":
        L_attn = cfg.n_enc_layers + 2 * cfg.n_dec_layers
        per_layer_fwd = 4.0 * B * S * S * H * hd / 2.0
    elif cfg.family in ("ssm",):
        return 0.0
    else:
        L_attn = cfg.n_layers
        per_layer_fwd = 4.0 * B * S * S * H * hd / 2.0
    if kind == "train":
        return 4.0 * L_attn * per_layer_fwd
    if kind == "prefill":
        return L_attn * per_layer_fwd
    # decode: one query over the full cache
    return L_attn * 4.0 * B * S * H * hd


def model_flops(cfg, abstract_params, kind: str, global_batch: int,
                seq_len: int, n_devices: int) -> float:
    n_act = active_params(cfg, abstract_params)
    if kind == "train":
        total = 6.0 * n_act * global_batch * seq_len
    elif kind == "prefill":
        total = 2.0 * n_act * global_batch * seq_len
    else:  # decode: one token per sequence
        total = 2.0 * n_act * global_batch
    total += _attention_flops(cfg, kind, global_batch, seq_len)
    return total / n_devices
