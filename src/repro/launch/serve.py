"""Serving CLI: continuous-batching engine or the oneshot reference driver.

    # continuous batching (default engine)
    PYTHONPATH=src python -m repro.launch.serve --arch gemma-7b --smoke \
        --engine continuous --slots 4 --requests 8 --prompt-len 32 --gen 16

    # legacy oneshot driver (fixed batch, lockstep decode) — kept as the
    # equivalence reference for the engine
    PYTHONPATH=src python -m repro.launch.serve --arch gemma-7b --smoke \
        --engine oneshot --batch 4 --prompt-len 32 --gen 16

Quantized serving: ``--quant-fmt luq_fp4 --backend pallas`` routes the
logits head through the quantizer-backend dispatcher's fused
quantize-matmul (``repro.quant.backend``) on either engine;
``REPRO_QUANT_BACKEND`` overrides ``--backend``.  Independently,
``--kv-fmt int8|luq_fp4`` stores the KV cache itself quantized (codes +
per-row bf16 scales) and decodes through the dispatched ``decode_attn``
op — fused dequant-attention on the pallas backend.  See docs/SERVING.md
for the engine's slot lifecycle and docs/QUANTIZATION.md for the
dispatch rules.

The engine logic lives in ``repro.serve``; this module only parses flags,
builds the model, and prints results.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import (DPConfig, OptimConfig, QuantConfig, RunConfig,
                          ServeConfig)
from repro.configs import get_config, get_smoke_config, list_archs
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model
from repro.runtime.faults import FaultPlan
from repro.runtime.supervisor import ServeSupervisor, run_supervised
from repro.serve import ContinuousEngine, build_oneshot_fns, oneshot_generate


def _random_prompt(key, length: int, vocab: int) -> np.ndarray:
    return np.asarray(jax.random.randint(key, (length,), 0, vocab),
                      np.int32)


def _random_batch(model, key, batch: int, prompt_len: int) -> dict:
    """Synthetic inputs for every key the model's batch_spec declares
    (int32 -> token ids, float -> gaussian; vlm/encdec need both)."""
    out = {}
    for k, sds in model.batch_spec(batch, prompt_len).items():
        if sds.dtype == jnp.int32:
            out[k] = jax.random.randint(jax.random.fold_in(key, 1),
                                        sds.shape, 0,
                                        model.config.vocab_size)
        else:
            out[k] = jax.random.normal(jax.random.fold_in(key, 2),
                                       sds.shape, sds.dtype)
    return out


def run_oneshot(model, params, mesh, run, args) -> None:
    """Legacy path: one fixed batch, synchronous prefill, lockstep decode."""
    cache_len = args.prompt_len + args.gen
    prefill, decode = build_oneshot_fns(model, run, mesh, args.batch,
                                        cache_len, kv_fmt=args.kv_fmt)
    key = jax.random.PRNGKey(args.seed)
    batch = _random_batch(model, key, args.batch, args.prompt_len)
    gen, timings = oneshot_generate(prefill, decode, params, batch, args.gen,
                                    temperature=args.temperature,
                                    base_key=key)
    print(f"prefill: {timings['prefill_s']*1e3:.1f} ms "
          f"for {args.batch}x{args.prompt_len}")
    print(f"decode:  {timings['decode_s']*1e3:.1f} ms for {args.gen-1} steps "
          f"({(args.gen-1)*args.batch/max(timings['decode_s'],1e-9):.1f} "
          f"tok/s)")
    print("generated token ids:\n", gen)


def run_continuous(model, params, args) -> None:
    """Continuous-batching path: slot-pool engine with FCFS admission.

    With ``--fault-seed`` the run goes through the supervisor under a
    seeded ``FaultPlan`` (chaos mode): faults are injected at their
    scheduled counters, recovery counters are printed, and the fired-event
    log lands in ``--fault-log`` for inspection.
    """
    serve = ServeConfig(max_slots=args.slots,
                        max_seq=args.prompt_len + args.gen,
                        max_new_tokens=args.gen,
                        temperature=args.temperature, seed=args.seed,
                        kv_fmt=args.kv_fmt,
                        deadline_s=args.deadline,
                        max_queue=args.max_queue)
    faults = None
    if args.fault_seed is not None:
        faults = FaultPlan.generate(
            args.fault_seed,
            kinds=("prefill_fail", "decode_fail", "slot_corrupt",
                   "clock_freeze"),
            horizon=max(2, args.gen), n_slots=args.slots)
    engine = ContinuousEngine(model, params, serve, faults=faults)
    supervisor = (ServeSupervisor(engine, faults=faults)
                  if faults is not None else None)
    key = jax.random.PRNGKey(args.seed)
    n_requests = args.requests or args.slots
    for i in range(n_requests):
        engine.submit(_random_prompt(jax.random.fold_in(key, 1 + i),
                                     args.prompt_len,
                                     model.config.vocab_size),
                      max_new_tokens=args.gen)
    results = (run_supervised(engine) if supervisor is not None
               else engine.run())
    summary = engine.metrics.summary()
    print(f"served {summary['n_requests']} requests / "
          f"{summary['total_new_tokens']} new tokens in "
          f"{summary['run_wall_s']*1e3:.1f} ms "
          f"({summary['tokens_per_sec']:.1f} tok/s, "
          f"{summary['decode_ticks']} decode ticks)")
    print(f"latency p50/p99: {summary['latency_p50_s']*1e3:.1f}/"
          f"{summary['latency_p99_s']*1e3:.1f} ms; "
          f"ttft p50: {summary['ttft_p50_s']*1e3:.1f} ms")
    if faults is not None or summary["shed"] or summary["deadline_missed"]:
        print(f"recovery: {summary['faults_injected']} faults injected, "
              f"{summary['retried']} retries, {summary['recovered']} "
              f"recovered, {summary['shed']} shed, "
              f"{summary['deadline_missed']} deadline-missed, "
              f"{summary['degraded_events']} degraded events")
    if faults is not None and args.fault_log:
        with open(args.fault_log, "w") as f:
            f.write(faults.log_json(extra={"summary": summary}))
        print(f"fault log written to {args.fault_log}")
    for rid in sorted(results):
        r = results[rid]
        tag = "" if r.status == "ok" else f" [{r.status}]"
        print(f"request {rid}{tag}: {r.tokens.tolist()}")


def main(argv=None):
    """Parse flags, build the model, and dispatch to the chosen engine."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", default="continuous",
                    choices=["continuous", "oneshot"],
                    help="continuous = slot-pool engine (repro.serve); "
                         "oneshot = legacy fixed-batch lockstep driver")
    ap.add_argument("--batch", type=int, default=4,
                    help="oneshot: fixed batch size")
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous: slot-pool size (decode batch width)")
    ap.add_argument("--requests", type=int, default=0,
                    help="continuous: number of requests (0 = --slots)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--quant-fmt", default="none",
                    help="logits-head quantization format for serving "
                         "(none | luq_fp4 | int4 | fp8_e4m3 | fp8_e5m2 | "
                         "bf16)")
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas"],
                    help="quantizer backend for --quant-fmt "
                         "(REPRO_QUANT_BACKEND overrides)")
    ap.add_argument("--kv-fmt", default="none",
                    choices=["none", "int8", "luq_fp4"],
                    help="KV-cache storage format (both engines): "
                         "quantized caches store codes + per-row bf16 "
                         "scales and attend through the dispatched "
                         "decode_attn op (docs/SERVING.md)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline", type=float, default=None,
                    help="continuous: per-request deadline in seconds from "
                         "arrival (expired requests retire with partial "
                         "results, status timed_out)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="continuous: bound on waiting requests; overflow "
                         "is shed at submit (0 = unbounded)")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="continuous: run under a seeded FaultPlan via the "
                         "supervisor (chaos mode)")
    ap.add_argument("--fault-log", default=None,
                    help="chaos mode: write the fired-fault JSON log here")
    args = ap.parse_args(argv)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    if not cfg.has_decoder:
        raise SystemExit(f"{args.arch} has no decoder; nothing to serve")
    quant = QuantConfig(fmt=args.quant_fmt, backend=args.backend)
    model = build_model(cfg, quant)
    params = model.init(jax.random.PRNGKey(args.seed))

    engine = args.engine
    if engine == "continuous" and model.decode_slots is None:
        # only the dense transformer implements slot decoding so far;
        # other decoder families keep working through the legacy driver
        print(f"note: {cfg.family!r} has no continuous-batching support "
              "yet; falling back to --engine oneshot")
        engine = "oneshot"

    if engine == "oneshot":
        mesh = make_host_mesh()
        run = RunConfig(model=cfg, quant=quant,
                        dp=DPConfig(enabled=False), optim=OptimConfig())
        run_oneshot(model, params, mesh, run, args)
    else:
        run_continuous(model, params, args)


if __name__ == "__main__":
    main()
