"""Serving driver: batched prefill + decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-7b --smoke \
        --batch 4 --prompt-len 32 --gen 16

Quantized serving: ``--quant-fmt luq_fp4 --backend pallas`` routes the
logits head projection through the quantizer-backend dispatcher's fused
quantize-matmul (``repro.quant.backend`` op ``"matmul"``) — on the pallas
backend both operands are LUQ-quantized tile-by-tile in VMEM fused with the
MXU contraction.  ``--backend ref`` runs the same dispatch through the
pure-jnp quantizers (the numerical reference); ``REPRO_QUANT_BACKEND``
overrides either.

Uses the host mesh; the full-scale configs are exercised via the dry-run
(launch/dryrun.py) which lowers the same prefill/decode functions on the
production mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import QuantConfig, RunConfig, DPConfig, OptimConfig
from repro.configs import get_config, get_smoke_config, list_archs
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_serve_setup
from repro.models.registry import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--quant-fmt", default="none",
                    help="logits-head quantization format for serving "
                         "(none | luq_fp4 | int4 | fp8_e4m3 | fp8_e5m2 | "
                         "bf16)")
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas"],
                    help="quantizer backend for --quant-fmt "
                         "(REPRO_QUANT_BACKEND overrides)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    if not cfg.has_decoder:
        raise SystemExit(f"{args.arch} has no decoder; nothing to serve")
    quant = QuantConfig(fmt=args.quant_fmt, backend=args.backend)
    model = build_model(cfg, quant)
    mesh = make_host_mesh()
    run = RunConfig(model=cfg, quant=quant,
                    dp=DPConfig(enabled=False), optim=OptimConfig())
    cache_len = args.prompt_len + args.gen
    setup = build_serve_setup(model, run, mesh, args.batch, cache_len)
    prefill = jax.jit(setup.prefill_fn)
    decode = jax.jit(setup.decode_fn)

    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    batch = {}
    for k, sds in model.batch_spec(args.batch, args.prompt_len).items():
        if sds.dtype == jnp.int32:
            batch[k] = jax.random.randint(jax.random.fold_in(key, 1),
                                          sds.shape, 0, cfg.vocab_size)
        else:
            batch[k] = jax.random.normal(jax.random.fold_in(key, 2),
                                         sds.shape, sds.dtype)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    generated = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        if args.temperature > 0:
            k = jax.random.fold_in(key, 100 + i)
            tok = jax.random.categorical(
                k, logits / args.temperature).astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        generated.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = np.stack(generated, 1)
    print(f"prefill: {t_prefill*1e3:.1f} ms for {args.batch}x{args.prompt_len}")
    print(f"decode:  {t_decode*1e3:.1f} ms for {args.gen-1} steps "
          f"({(args.gen-1)*args.batch/max(t_decode,1e-9):.1f} tok/s)")
    print("generated token ids:\n", gen)


if __name__ == "__main__":
    main()
