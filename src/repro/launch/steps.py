"""Step builders: jit-ready train / prefill / decode functions + shardings.

``build_train_setup``/``build_serve_setup`` assemble, for a (model, mesh):
  * the pure step function (DP-SGD/DP-Adam or plain),
  * in/out NamedShardings derived from logical axes via the partitioner,
  * abstract (ShapeDtypeStruct) arguments for ``jit(...).lower()`` —
    the multi-pod dry-run and the roofline derive everything from these.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import RunConfig
from repro.dp.clip import per_example_clipped_grad_sum
from repro.dp.engine import validate_grad_mode
from repro.dp.ghost import (ghost_clipped_grad_sum,
                            sharded_ghost_clipped_grad_sum)
from repro.dp.noise import add_gaussian_noise
from repro.models.registry import Model
from repro.optim import make_optimizer, apply_updates
from repro.optim.optimizers import AdamState
from repro.parallel import partitioner as pt
from repro.parallel.axes import partitioning_context


def _replicated(mesh):
    return NamedSharding(mesh, P())


def _opt_axes(opt_name: str, paxes):
    if opt_name in ("sgd",):
        return ()
    if opt_name == "momentum":
        return paxes
    return AdamState(paxes, paxes, None)


@dataclasses.dataclass
class TrainSetup:
    step_fn: Callable
    in_shardings: Tuple
    out_shardings: Tuple
    abstract_args: Tuple
    mesh: Mesh
    rules: dict
    init_fn: Callable           # sharding-annotated param init
    opt_init_fn: Callable


def _microbatch(run: RunConfig, mesh: Mesh) -> int:
    dp_axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_degree = 1
    for a in dp_axes:
        dp_degree *= sizes[a]
    if run.dp.microbatch_mode == "single":
        return 1
    mb = run.dp.microbatch_size * dp_degree
    return max(1, min(mb, run.global_batch))


def build_train_setup(model: Model, run: RunConfig, mesh: Mesh,
                      batch_size: Optional[int] = None,
                      seq_len: Optional[int] = None) -> TrainSetup:
    cfg = model.config
    if run.dp.enabled:
        validate_grad_mode(run.dp, model)
    rules = pt.merge_rules(pt.DEFAULT_RULES, cfg.sharding_overrides)
    resolver = pt.activation_resolver(mesh, rules)
    opt = make_optimizer(run.optim)
    B = batch_size or run.global_batch
    S = seq_len or run.seq_len
    mb = _microbatch(run, mesh)
    n_layers = cfg.policy_len()
    accum_dtype = jnp.dtype(run.dp.grad_accum_dtype)

    # ---- abstract shapes ----
    abstract_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    abstract_opt = jax.eval_shape(opt.init, abstract_params)
    abstract_batch = model.batch_spec(B, S)
    abstract_args = (
        abstract_params, abstract_opt, abstract_batch,
        jax.ShapeDtypeStruct((), jnp.uint32),       # seed
        jax.ShapeDtypeStruct((n_layers,), jnp.float32),  # qflags
        jax.ShapeDtypeStruct((), jnp.float32),      # lr
    )

    # ---- shardings ----
    paxes = model.param_axes()
    param_sh = pt.tree_shardings(paxes, abstract_params, mesh, rules)
    opt_sh = pt.tree_shardings(_opt_axes(opt.name, paxes), abstract_opt,
                               mesh, rules)
    batch_sh = pt.tree_shardings(model.batch_axes(), abstract_batch,
                                 mesh, rules)
    rep = _replicated(mesh)
    in_shardings = (param_sh, opt_sh, batch_sh, rep, rep, rep)
    out_shardings = (param_sh, opt_sh, None)

    def micro_constrain(micro):
        """Keep the microbatch example-dim data-sharded after the reshape."""
        def one(x, ax):
            logical = (None, "batch") + tuple(ax[1:])
            return jax.lax.with_sharding_constraint(
                x, pt.named_sharding(logical, x.shape, mesh, rules))
        return jax.tree_util.tree_map(one, micro, model.batch_axes())

    dp_shards = 1
    _sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for _a in ("pod", "data"):
        dp_shards *= _sizes.get(_a, 1)
    _axes_leaf = lambda x: x is None or (isinstance(x, tuple) and len(x) > 0
                                         and all(isinstance(e, (str, type(None)))
                                                 for e in x))

    def partial_constrain(tree):
        """Partial grad sums: leading shard dim over (pod, data); param dims
        keep their own sharding."""
        def one(ax, x):
            logical = ("batch",) + tuple(ax or [None] * (x.ndim - 1))
            if len(logical) != x.ndim:
                return x
            try:
                sh = pt.named_sharding(logical, x.shape, mesh, rules)
            except ValueError:
                return x
            return jax.lax.with_sharding_constraint(x, sh)
        return jax.tree_util.tree_map(one, paxes, tree, is_leaf=_axes_leaf)

    # ---- ghost-mode execution strategy (docs/ARCHITECTURE.md) ----
    # sharded: shard_map over the data axes (per-shard norm taps + one
    # psum) when the mesh actually data-parallelizes and params are not
    # model-sharded; otherwise the GSPMD driver with a sharding-constrained
    # pass-2 batch.  ghost_microbatch chunks pass 1 either way.
    model_degree = _sizes.get("model", 1)
    gs = run.dp.ghost_sharded
    ghost_is_on = run.dp.enabled and run.dp.grad_mode == "ghost"
    if ghost_is_on and gs == "on" and model_degree > 1:
        raise ValueError("dp.ghost_sharded='on' requires params replicated "
                         "over the data axes (model axis degree 1); use "
                         "'auto'/'off' on model-parallel meshes")
    if gs == "on":
        ghost_use_sharded = ghost_is_on   # divisibility checked in-driver
    else:
        ghost_use_sharded = (gs == "auto" and ghost_is_on and dp_shards > 1
                             and model_degree == 1
                             and B % dp_shards == 0)
    ghost_mb_local = run.dp.ghost_microbatch

    def ghost_batch_constrain(b):
        return jax.tree_util.tree_map(jax.lax.with_sharding_constraint,
                                      b, batch_sh)

    def train_step(params, opt_state, batch, seed, qflags, lr):
        with partitioning_context(resolver):
            rng = jax.random.PRNGKey(seed)
            clip_rng, noise_rng, loss_rng = jax.random.split(rng, 3)

            def loss_one(p, ex, r):
                b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
                return model.loss_fn(p, b1, r, qflags)

            if run.dp.enabled and run.dp.grad_mode == "ghost":
                def pel(p, b, r):
                    return model.per_example_loss(p, b, r, qflags)

                aux = (model.ghost_aux(qflags)
                       if model.ghost_aux is not None else None)
                if ghost_use_sharded:
                    grad_sum, metrics = sharded_ghost_clipped_grad_sum(
                        loss_one, pel, params, batch,
                        clip_norm=run.dp.clip_norm, rng=clip_rng,
                        hooked_mask=model.ghost_mask(params),
                        mesh=mesh, data_axes=("pod", "data"),
                        accum_dtype=accum_dtype, aux=aux,
                        ghost_microbatch=ghost_mb_local)
                else:
                    grad_sum, metrics = ghost_clipped_grad_sum(
                        loss_one, pel, params, batch,
                        clip_norm=run.dp.clip_norm, rng=clip_rng,
                        hooked_mask=model.ghost_mask(params),
                        accum_dtype=accum_dtype, aux=aux,
                        ghost_microbatch=run.dp.ghost_microbatch,
                        constrain=ghost_batch_constrain)
                grads = add_gaussian_noise(
                    grad_sum, clip_norm=run.dp.clip_norm,
                    noise_multiplier=run.dp.noise_multiplier,
                    batch_size=B, rng=noise_rng)
            elif run.dp.enabled:
                grad_sum, metrics = per_example_clipped_grad_sum(
                    loss_one, params, batch,
                    clip_norm=run.dp.clip_norm, microbatch_size=mb,
                    rng=clip_rng, constrain=micro_constrain,
                    accum_dtype=accum_dtype,
                    partial_accum_shards=(dp_shards if run.dp.partial_accum
                                          else 0),
                    constrain_partial=partial_constrain,
                    clip_backend=run.dp.clip_backend)
                grads = add_gaussian_noise(
                    grad_sum, clip_norm=run.dp.clip_norm,
                    noise_multiplier=run.dp.noise_multiplier,
                    batch_size=B, rng=noise_rng)
            else:
                def mean_loss(p):
                    return model.loss_fn(p, batch, loss_rng, qflags)
                loss, grads = jax.value_and_grad(mean_loss)(params)
                metrics = {"loss": loss}

            updates, new_opt = opt.update(grads, opt_state, params, lr)
            new_params = apply_updates(params, updates)
            return new_params, new_opt, metrics

    def init_fn(key):
        return model.init(key)

    return TrainSetup(
        step_fn=train_step, in_shardings=in_shardings,
        out_shardings=out_shardings, abstract_args=abstract_args,
        mesh=mesh, rules=rules, init_fn=init_fn, opt_init_fn=opt.init)


def build_epoch_fn(setup: TrainSetup, *, unroll: int = 1):
    """Compile a whole epoch (or chunk of steps) into one scan program.

    Returns a jitted function

        ``epoch_fn(params, opt_state, batches, seeds, qflags, lrs)
            -> (params, opt_state, metrics)``

    where ``batches`` is the epoch's pre-drawn batch tree with a leading
    ``steps`` axis, ``seeds``/``lrs`` are per-step ``(steps,)`` arrays, and
    ``metrics`` holds every per-step metric stacked on device.  The body is
    exactly ``setup.step_fn`` — the same traced computation the per-step
    executor jits — scanned over the step axis, so the two executors are
    numerically interchangeable.  ``params``/``opt_state`` buffers are
    donated: the epoch program updates them in place instead of allocating
    a second copy of the model per step.

    ``unroll`` is forwarded to ``jax.lax.scan``: unrolling k step bodies per
    loop iteration removes while-loop overhead and lets XLA overlap the
    params-independent work of adjacent steps (batch dequant, PRNG,
    DP-noise generation); it trades compile time for throughput, so the
    default stays 1 and the benchmark/production configs opt in.

    The epoch program carries the same shardings as the per-step jit:
    params/opt keep ``setup``'s tree shardings and the stacked batches get
    the per-step batch sharding with a replicated leading step axis, so on
    a multi-device mesh the scan executor partitions exactly like the
    legacy loop instead of falling back to unannotated placement.
    """
    param_sh, opt_sh, batch_sh = setup.in_shardings[:3]
    stacked_batch_sh = jax.tree_util.tree_map(
        lambda sh: NamedSharding(sh.mesh, P(None, *sh.spec)), batch_sh)
    rep = _replicated(setup.mesh)

    def epoch_fn(params, opt_state, batches, seeds, qflags, lrs):
        def body(carry, xs):
            p, o = carry
            batch, seed, lr = xs
            p, o, metrics = setup.step_fn(p, o, batch, seed, qflags, lr)
            return (p, o), metrics

        (params, opt_state), metrics = jax.lax.scan(
            body, (params, opt_state), (batches, seeds, lrs),
            unroll=unroll)
        return params, opt_state, metrics

    return jax.jit(
        epoch_fn,
        in_shardings=(param_sh, opt_sh, stacked_batch_sh, rep, rep, rep),
        out_shardings=setup.out_shardings,
        donate_argnums=(0, 1))


@dataclasses.dataclass
class ServeSetup:
    prefill_fn: Callable
    decode_fn: Callable
    prefill_in_shardings: Tuple
    prefill_abstract: Tuple
    decode_in_shardings: Tuple
    decode_abstract: Tuple
    mesh: Mesh
    rules: dict


def build_serve_setup(model: Model, run: RunConfig, mesh: Mesh,
                      batch_size: int, seq_len: int,
                      kv_fmt: str = "none") -> ServeSetup:
    cfg = model.config
    rules = pt.merge_rules(pt.DEFAULT_RULES, cfg.sharding_overrides)
    resolver = pt.activation_resolver(mesh, rules)

    if kv_fmt not in model.kv_formats:
        raise ValueError(
            f"model family {cfg.family!r} does not support "
            f"kv_fmt={kv_fmt!r} (supported: {model.kv_formats})")
    # Only pass the kwarg for quantized formats so ("none",)-only families
    # keep their original zero-extra-arg serve hook signatures.
    kv_kw = {} if kv_fmt == "none" else {"kv_fmt": kv_fmt}

    abstract_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    param_sh = pt.tree_shardings(model.param_axes(), abstract_params,
                                 mesh, rules)
    abstract_batch = model.batch_spec(batch_size, seq_len)
    batch_sh = pt.tree_shardings(model.batch_axes(), abstract_batch,
                                 mesh, rules)
    abstract_cache = model.cache_spec(batch_size, seq_len, **kv_kw)
    cache_sh = pt.tree_shardings(model.cache_axes(**kv_kw), abstract_cache,
                                 mesh, rules)
    token_sh = pt.named_sharding(("batch",), (batch_size,), mesh, rules)

    def prefill_fn(params, batch):
        with partitioning_context(resolver):
            return model.prefill(params, batch, cache_len=seq_len, **kv_kw)

    def decode_fn(params, cache, token):
        with partitioning_context(resolver):
            return model.decode_step(params, cache, token, **kv_kw)

    return ServeSetup(
        prefill_fn=prefill_fn, decode_fn=decode_fn,
        prefill_in_shardings=(param_sh, batch_sh),
        prefill_abstract=(abstract_params, abstract_batch),
        decode_in_shardings=(param_sh, cache_sh, token_sh),
        decode_abstract=(abstract_params, abstract_cache,
                         jax.ShapeDtypeStruct((batch_size,), jnp.int32)),
        mesh=mesh, rules=rules)
