"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train \
        --arch resnet18 --mode dpquant --epochs 10 --eps 8 \
        --quant-fraction 0.9 --fmt luq_fp4 --checkpoint-dir ckpt/

Any registered arch id works (use --smoke for the reduced config — the full
LM configs need the production mesh).  Restores from the latest valid
checkpoint automatically (fault-tolerant restart).
"""
from __future__ import annotations

import argparse

from repro.config import DPConfig, ModelConfig, OptimConfig, QuantConfig, RunConfig
from repro.configs import get_config, get_smoke_config, list_archs
from repro.data.synthetic import ImageClassDataset, NLIDataset, TokenDataset
from repro.runtime.faults import FaultEvent, FaultPlan
from repro.runtime.preemption import Preempted, PreemptionHandler
from repro.train_loop import Trainer


def make_dataset(cfg: ModelConfig, n: int, seq_len: int, seed: int = 0):
    if cfg.family in ("resnet", "densenet"):
        return ImageClassDataset(n=n, num_classes=cfg.num_classes,
                                 image_size=cfg.image_size, seed=seed)
    if cfg.family == "bert":
        return NLIDataset(n=n, vocab=cfg.vocab_size, seq_len=seq_len,
                          num_classes=cfg.num_classes, seed=seed)
    return TokenDataset(n=n, vocab=cfg.vocab_size, seq_len=seq_len, seed=seed)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-scale)")
    ap.add_argument("--mode", default="dpquant",
                    choices=["dpquant", "pls", "static"])
    ap.add_argument("--no-dp", action="store_true")
    ap.add_argument("--fmt", default="luq_fp4")
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas"],
                    help="quantizer backend (repro.quant.backend dispatch); "
                         "REPRO_QUANT_BACKEND overrides")
    ap.add_argument("--clip-backend", default="ref",
                    choices=["ref", "fused"],
                    help="per-example clip path: jnp reference or the fused "
                         "Pallas clip+sum kernel")
    ap.add_argument("--grad-mode", default="vmap",
                    choices=["vmap", "ghost"],
                    help="per-example gradient engine: vmap(grad) "
                         "materialization or two-pass ghost-norm clipping "
                         "(docs/ARCHITECTURE.md 'DP gradient modes')")
    ap.add_argument("--ghost-microbatch", type=int, default=0,
                    help="ghost pass-1 chunk size (0 = whole batch): scans "
                         "the norm pass in chunks so activations alone "
                         "bound ghost memory")
    ap.add_argument("--ghost-sharded", default="auto",
                    choices=["auto", "on", "off"],
                    help="data-parallel ghost formulation: shard_map with "
                         "per-shard norm taps + one psum of the clipped "
                         "grad sums (auto = when the mesh data axes have "
                         "degree > 1)")
    ap.add_argument("--quant-fraction", type=float, default=0.9)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--steps-per-epoch", type=int, default=10)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--dataset-size", type=int, default=4096)
    ap.add_argument("--lr", type=float, default=0.5)
    ap.add_argument("--optimizer", default="sgd",
                    choices=["sgd", "momentum", "adam", "adamw"])
    ap.add_argument("--clip-norm", type=float, default=1.0)
    ap.add_argument("--noise-multiplier", type=float, default=1.0)
    ap.add_argument("--eps", type=float, default=None,
                    help="stop when the privacy budget is reached")
    ap.add_argument("--microbatch", type=int, default=16)
    ap.add_argument("--executor", default="scan", choices=["scan", "loop"],
                    help="epoch executor: one compiled scan per epoch "
                         "(default) or the legacy per-step loop")
    ap.add_argument("--epoch-chunk", type=int, default=0,
                    help="scan chunk size in steps (0 = whole epoch)")
    ap.add_argument("--epoch-unroll", type=int, default=1,
                    help="lax.scan unroll factor for the scan executor")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--preempt-at", type=int, default=None,
                    help="inject a preemption at this global step: the "
                         "trainer writes a mid-epoch checkpoint and exits; "
                         "a rerun resumes bit-identically")
    ap.add_argument("--handle-signals", action="store_true",
                    help="checkpoint-and-exit on SIGTERM (scheduler "
                         "eviction notice) instead of dying mid-step")
    args = ap.parse_args(argv)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    run = RunConfig(
        model=cfg,
        quant=QuantConfig(fmt=args.fmt, backend=args.backend),
        dp=DPConfig(enabled=not args.no_dp, clip_norm=args.clip_norm,
                    noise_multiplier=args.noise_multiplier,
                    microbatch_size=args.microbatch,
                    quant_fraction=args.quant_fraction,
                    clip_backend=args.clip_backend,
                    grad_mode=args.grad_mode,
                    ghost_microbatch=args.ghost_microbatch,
                    ghost_sharded=args.ghost_sharded),
        optim=OptimConfig(name=args.optimizer, lr=args.lr),
        global_batch=args.batch, seq_len=args.seq_len,
        steps_per_epoch=args.steps_per_epoch,
        steps=args.epochs * args.steps_per_epoch, seed=args.seed,
        epoch_executor=args.executor, epoch_chunk=args.epoch_chunk,
        epoch_unroll=args.epoch_unroll)

    ds = make_dataset(cfg, args.dataset_size, args.seq_len, args.seed)
    ev = make_dataset(cfg, 512, args.seq_len, args.seed + 1) \
        if cfg.family in ("resnet", "densenet", "bert") else None
    handler = None
    if args.preempt_at is not None or args.handle_signals:
        plan = (FaultPlan([FaultEvent(kind="preempt", at=args.preempt_at)],
                          seed=args.seed)
                if args.preempt_at is not None else None)
        handler = PreemptionHandler(faults=plan,
                                    handle_signals=args.handle_signals)
    tr = Trainer(run, ds, eval_dataset=ev, mode=args.mode,
                 checkpoint_dir=args.checkpoint_dir, preemption=handler)
    resumed = tr.restore_latest()
    if resumed is not None:
        print(f"resumed from checkpoint at epoch {resumed}"
              + (" (mid-epoch)" if tr._mid_epoch is not None else ""))
    # --epochs is the run's *total* epoch count: train whatever is left
    # past the epoch cursor (a finished run is a clean no-op restart)
    remaining = max(0, args.epochs - tr._next_epoch)
    try:
        tr.train(remaining, eps_budget=args.eps, verbose=True)
    except Preempted as p:
        if tr.ckpt:
            tr.ckpt.wait()
        print(f"preempted at step {p.step}; checkpoint written — rerun to "
              "resume")
        return
    if tr.ckpt:
        tr.ckpt.wait()
    final = tr.history[-1]
    print(f"final: loss={final.loss:.4f} eps={final.eps:.3f} "
          f"acc={final.accuracy}")


if __name__ == "__main__":
    main()
