from repro.models.registry import Model, build_model, register_family

__all__ = ["Model", "build_model", "register_family"]
