"""BERT-style encoder for sequence classification (paper's SNLI experiment).

Pre-LN encoder, learned position embeddings, [CLS] (position 0) pooled
classification head.  The paper freezes all but the last encoder layer
(Opacus tutorial recipe); ``bert_trainable_last_only`` reproduces that via
``stop_gradient`` on the frozen stack — their per-example grads are exactly
zero and clipping/noise behave identically to Opacus' frozen modules.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, QuantConfig
from repro.models import common as cm
from repro.models import transformer as tfm
from repro.models.registry import Model, register_family


def init_params(key, cfg: ModelConfig):
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    blocks = tfm.init_block_stack(ks[0], cfg, cfg.n_layers)
    # bert mlp is plain gelu: reuse gate as the single projection
    return {
        "embed": cm.embed_init(ks[1], (cfg.padded_vocab, cfg.d_model), pdt),
        "pos_embed": cm.embed_init(ks[2], (cfg.max_position, cfg.d_model), pdt),
        "blocks": blocks,
        "final_norm": jnp.zeros((cfg.d_model,), pdt),
        "cls_w": cm.dense_init(ks[3], (cfg.d_model, cfg.num_classes),
                               cfg.d_model, jnp.float32),
        "cls_b": jnp.zeros((cfg.num_classes,), jnp.float32),
    }


def param_axes(cfg: ModelConfig):
    return {
        "embed": ("vocab", "embed"),
        "pos_embed": (None, "embed"),
        "blocks": dict(tfm.BLOCK_AXES),
        "final_norm": ("embed",),
        "cls_w": ("embed", None),
        "cls_b": (None,),
    }


def bert_block(x, blk, flag, lidx, positions, cfg, quant):
    """Bidirectional attention + GeLU MLP (pre-LN)."""
    seed = lidx.astype(jnp.uint32) * jnp.uint32(97)
    qp = functools.partial(cm.qproj, quant_cfg=quant, flag=flag)
    cd = x.dtype
    h = cm.rmsnorm(x, blk["attn_norm"]).astype(cd)
    q = qp("bsd,dhk->bshk", h, blk["wq"].astype(cd), seed=seed)
    k = qp("bsd,dhk->bshk", h, blk["wk"].astype(cd), seed=seed + 1)
    v = qp("bsd,dhk->bshk", h, blk["wv"].astype(cd), seed=seed + 2)
    out = cm.chunked_causal_attention(
        q, k, v, chunk_q=cfg.attn_chunk_q, causal=False,
        scale=1.0 / math.sqrt(cfg.head_dim))
    x = x + qp("bshk,hkd->bsd", out, blk["wo"].astype(cd), seed=seed + 3)
    h2 = cm.rmsnorm(x, blk["mlp_norm"]).astype(cd)
    a = jax.nn.gelu(qp("bsd,df->bsf", h2, blk["wi_gate"].astype(cd),
                       seed=seed + 4))
    return x + qp("bsf,fd->bsd", a, blk["wo_mlp"].astype(cd), seed=seed + 5)


def forward(params, tokens, qflags, cfg: ModelConfig, quant: QuantConfig,
            trainable_last_only: bool = False):
    cd = jnp.dtype(cfg.compute_dtype)
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    x = x + params["pos_embed"][:S][None].astype(cd)
    positions = jnp.arange(S)[None, :]
    blocks = params["blocks"]
    if trainable_last_only:
        # freeze all but the last encoder layer (paper A.4.2)
        frozen = jax.tree_util.tree_map(
            lambda p: jax.lax.stop_gradient(p[:-1]), blocks)
        last = jax.tree_util.tree_map(lambda p: p[-1:], blocks)
        blocks = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], axis=0), frozen, last)
    x = tfm.run_block_stack(x, blocks, qflags, positions, cfg, quant,
                            block_fn=bert_block)
    return cm.rmsnorm(x, params["final_norm"])


def loss_fn(params, batch, rng, qflags, cfg: ModelConfig, quant: QuantConfig,
            trainable_last_only: bool = False):
    del rng
    h = forward(params, batch["tokens"], qflags, cfg, quant,
                trainable_last_only)
    cls = h[:, 0].astype(jnp.float32)
    logits = cls @ params["cls_w"] + params["cls_b"]
    return cm.softmax_xent(logits, batch["label"])


@register_family("bert")
def build_bert(cfg: ModelConfig, quant: QuantConfig) -> Model:
    def batch_spec(batch: int, seq: int):
        seq = min(seq or cfg.max_position, cfg.max_position)
        return {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
                "label": jax.ShapeDtypeStruct((batch,), jnp.int32)}

    def batch_axes():
        return {"tokens": ("batch", "seq"), "label": ("batch",)}

    return Model(
        config=cfg, quant=quant,
        init=functools.partial(init_params, cfg=cfg),
        param_axes=lambda: param_axes(cfg),
        loss_fn=functools.partial(loss_fn, cfg=cfg, quant=quant),
        batch_spec=batch_spec,
        batch_axes=batch_axes,
    )
