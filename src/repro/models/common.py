"""Shared model components: norms, RoPE, attention, chunked losses.

Conventions
-----------
* Params are nested dicts of arrays; a parallel pytree of *logical axis name
  tuples* (strings) describes each leaf for the partitioner
  (repro.parallel.partitioner).
* Layer stacks are stored with a leading ``layers`` dim and executed with
  ``lax.scan`` (keeps HLO size O(1) in depth); DPQuant per-layer flags ride
  along as scan xs.
* Attention is computed in *query chunks* with statically-banded key ranges
  (exact causal FLOPs, flash-style memory) — see ``chunked_causal_attention``.
* The LM loss never materializes (B, S, V) logits: ``chunked_lm_loss``
  walks the sequence in chunks against the (possibly vocab-sharded) embedding.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.fake_quant import qeinsum


# --------------------------------------------------------------------------- #
# init helpers
# --------------------------------------------------------------------------- #
def dense_init(key, shape, in_axis_size, dtype=jnp.float32):
    std = 1.0 / math.sqrt(max(in_axis_size, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------------- #
def _rmsnorm_raw(x, scale, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def rmsnorm(x, scale, eps=1e-6):
    """RMSNorm; under a ghost norm pass with ``norm_scales`` enabled the
    scale leaf's per-example squared grad norm is tapped (repro.dp.ghost),
    output bits unchanged."""
    from repro.dp import ghost
    ctx = ghost.current()
    if (ctx is not None and getattr(ctx, "mode", None) == "norm"
            and getattr(ctx, "norm_scales", False)):
        return ghost.make_ghost_scale_norm(_rmsnorm_raw, eps)(
            x, scale, ctx.tap)
    return _rmsnorm_raw(x, scale, eps)


def layernorm(x, scale, bias, eps=1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return out.astype(x.dtype)


def groupnorm(x, scale, bias, groups=8, eps=1e-5):
    """GroupNorm over the channel (last) dim of NHWC tensors.

    BatchNorm leaks cross-example statistics and is incompatible with
    per-example DP gradients (Opacus imposes the same replacement).
    """
    b, h, w, c = x.shape
    g = math.gcd(groups, c)
    x32 = x.astype(jnp.float32).reshape(b, h, w, g, c // g)
    mu = x32.mean(axis=(1, 2, 4), keepdims=True)
    var = x32.var(axis=(1, 2, 4), keepdims=True)
    x32 = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (x32.reshape(b, h, w, c) * scale + bias).astype(x.dtype)


# --------------------------------------------------------------------------- #
# rope
# --------------------------------------------------------------------------- #
def rope(x, positions, theta=10_000.0):
    """Rotary embedding. x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len, d_model, offset=0):
    pos = jnp.arange(offset, offset + seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2 * dim / d_model)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------------- #
def _softmax_attend(q, k, v, mask, scale):
    """q: (B,Tq,H,D); k,v: (B,Tk,H,D); mask broadcastable (B,H,Tq,Tk)."""
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def chunked_causal_attention(q, k, v, *, chunk_q: int, causal: bool = True,
                             window: Optional[int] = None,
                             scale: Optional[float] = None):
    """Flash-style attention with exact-causal (banded) static key slices.

    The python loop over query chunks is unrolled at trace time; chunk ``i``
    only reads keys ``[max(0, lo_i) : (i+1)*chunk_q]`` so the compiled HLO
    carries exactly the causal/windowed FLOPs, and peak memory is one
    (B, chunk_q, H, Tk_i) score block.
    """
    b, s, h, d = q.shape
    tk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    cq = min(chunk_q, s)
    n_chunks = (s + cq - 1) // cq
    outs = []
    for i in range(n_chunks):
        q0, q1 = i * cq, min((i + 1) * cq, s)
        qc = q[:, q0:q1]
        k1 = min(q1, tk) if causal else tk
        k0 = 0
        if window is not None:
            k0 = max(0, q0 - window)
        kc, vc = k[:, k0:k1], v[:, k0:k1]
        qpos = jnp.arange(q0, q1)[:, None]
        kpos = jnp.arange(k0, k1)[None, :]
        mask = jnp.ones((q1 - q0, k1 - k0), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        outs.append(_softmax_attend(qc, kc, vc, mask[None, None], scale))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def repeat_kv(x, n_rep: int):
    """(B, S, KV, D) -> (B, S, KV*n_rep, D)."""
    if n_rep == 1:
        return x
    b, s, kv, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv, n_rep, d)) \
              .reshape(b, s, kv * n_rep, d)


# --------------------------------------------------------------------------- #
# losses
# --------------------------------------------------------------------------- #
def chunked_lm_loss(h, targets, embed, *, real_vocab: int, ce_chunk: int,
                    mask=None, per_example: bool = False, logits_tap=None):
    """Mean next-token cross-entropy without materializing (B, S, V).

    h: (B, S, d) hidden states aligned with ``targets`` (B, S) int32.
    embed: (V_pad, d) — logits = h @ embed.T computed per sequence chunk.
    ``mask``: optional (B, S) 0/1 loss mask.
    ``per_example=True`` returns the (B,) vector of per-example mean NLLs
    (each equal to the scalar loss of that example alone — the ghost
    grad-engine's reweighting target) instead of the batch mean.
    ``logits_tap``: ghost pass-1 hook (repro.dp.ghost.GhostAux) — a
    (B, S, V_pad) zero array added onto the raw logits; its cotangent is
    the logits cotangent the head wgrad consumes.  Forces a SINGLE
    sequence chunk (the single-chunk LM-head hook) and switches the
    return to ``(loss, hc)`` with ``hc`` the f32 hidden rows that entered
    the logits GEMM.
    """
    b, s, dm = h.shape
    vpad = embed.shape[0]
    cc = s if logits_tap is not None else min(ce_chunk, s)
    n_chunks = (s + cc - 1) // cc
    zero = jnp.zeros((b,), jnp.float32) if per_example else jnp.float32(0.0)
    total, denom = zero, zero
    reduce_axes = (1,) if per_example else None
    vocab_ids = jnp.arange(vpad)
    hc_out = None
    for i in range(n_chunks):
        s0, s1 = i * cc, min((i + 1) * cc, s)
        hc = h[:, s0:s1].astype(jnp.float32)
        logits = jnp.einsum("bsd,vd->bsv", hc, embed.astype(jnp.float32))
        if logits_tap is not None:
            logits = logits + logits_tap
            hc_out = hc
        logits = jnp.where(vocab_ids[None, None, :] < real_vocab,
                           logits, -1e30)
        tc = targets[:, s0:s1]
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = lse - tgt
        if mask is not None:
            mc = mask[:, s0:s1].astype(jnp.float32)
            total += (nll * mc).sum(axis=reduce_axes)
            denom += mc.sum(axis=reduce_axes)
        else:
            total += nll.sum(axis=reduce_axes)
            denom += jnp.float32(nll.size / b if per_example else nll.size)
    loss = total / jnp.maximum(denom, 1.0)
    if logits_tap is not None:
        return loss, hc_out
    return loss


def softmax_xent(logits, labels, per_example: bool = False):
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    tgt = jnp.take_along_axis(logits.astype(jnp.float32),
                              labels[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    return nll if per_example else nll.mean()


# --------------------------------------------------------------------------- #
# quantized projection helpers
# --------------------------------------------------------------------------- #
def qproj(spec, x, w, *, seed, flag, quant_cfg):
    """Policy-gated quantized einsum (see repro.quant.fake_quant)."""
    return qeinsum(spec, x, w, seed=seed, flag=flag, fmt=quant_cfg.fmt,
                   q_fwd=quant_cfg.quantize_fwd,
                   q_dgrad=quant_cfg.quantize_dgrad,
                   q_wgrad=quant_cfg.quantize_wgrad,
                   backend=quant_cfg.backend)


def qlogits(h, head, *, quant_cfg, key):
    """Serving logits projection through the quantizer-backend dispatcher.

    ``h``: (B, d) final hidden states; ``head``: (V, d) output embedding.
    With ``fmt="none"`` this is the exact fp32 einsum; otherwise both
    operands go through the dispatcher's fused quantize-matmul (on the
    pallas backend the LUQ quantization happens tile-by-tile in VMEM fused
    with the MXU contraction — the serve-path analogue of qeinsum).
    """
    h32 = h.astype(jnp.float32)
    head32 = head.astype(jnp.float32)
    if quant_cfg is None or quant_cfg.fmt == "none":
        return jnp.einsum("bd,vd->bv", h32, head32)
    from repro.quant import backend as qbackend
    mm, _ = qbackend.get_matmul(quant_cfg.fmt, quant_cfg.backend)
    return mm(h32, head32.T, key)
