"""DenseNet-121 (paper CNN), DP-compatible (GroupNorm).

Blocks (6, 12, 24, 16), growth 32, bottleneck 4x, compression 0.5.
DPQuant policy: each dense layer and each transition is one schedulable
layer (policy_len = sum(blocks) + len(blocks) = 62 for 121).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, QuantConfig
from repro.models import common as cm
from repro.models import resnet
from repro.models.registry import Model, register_family
from repro.quant.fake_quant import qconv2d


def _conv_init(key, shape):
    fan_in = shape[0] * shape[1] * shape[2]
    return jax.random.normal(key, shape, jnp.float32) * math.sqrt(2.0 / fan_in)


def _gn(c):
    return {"scale": jnp.ones((c,), jnp.float32),
            "bias": jnp.zeros((c,), jnp.float32)}


def init_params(key, cfg: ModelConfig):
    g = cfg.growth_rate
    bn_size = 4
    c = 2 * g
    keys = iter(jax.random.split(key, 4 * sum(cfg.densenet_blocks) + 16))
    params = {"stem": {"conv": _conv_init(next(keys), (3, 3, cfg.in_channels, c)),
                       "gn": _gn(c)}}
    blocks = []
    for bi, n in enumerate(cfg.densenet_blocks):
        layers = []
        for li in range(n):
            layers.append({
                "gn1": _gn(c),
                "conv1": _conv_init(next(keys), (1, 1, c, bn_size * g)),
                "gn2": _gn(bn_size * g),
                "conv2": _conv_init(next(keys), (3, 3, bn_size * g, g)),
            })
            c += g
        blk = {"layers": layers}
        if bi < len(cfg.densenet_blocks) - 1:
            out_c = c // 2
            blk["transition"] = {"gn": _gn(c),
                                 "conv": _conv_init(next(keys), (1, 1, c, out_c))}
            c = out_c
        blocks.append(blk)
    params["blocks"] = blocks
    params["final_gn"] = _gn(c)
    params["head"] = {"w": jax.random.normal(next(keys), (c, cfg.num_classes),
                                             jnp.float32) / math.sqrt(c),
                      "b": jnp.zeros((cfg.num_classes,), jnp.float32)}
    return params


def param_axes(cfg: ModelConfig):
    conv_ax = (None, None, None, "mlp")
    gn_ax = {"scale": (None,), "bias": (None,)}
    blocks = []
    for bi, n in enumerate(cfg.densenet_blocks):
        blk = {"layers": [{"gn1": gn_ax, "conv1": conv_ax,
                           "gn2": gn_ax, "conv2": conv_ax}
                          for _ in range(n)]}
        if bi < len(cfg.densenet_blocks) - 1:
            blk["transition"] = {"gn": gn_ax, "conv": conv_ax}
        blocks.append(blk)
    return {"stem": {"conv": conv_ax, "gn": gn_ax}, "blocks": blocks,
            "final_gn": gn_ax,
            "head": {"w": (None, None), "b": (None,)}}


def forward(params, image, qflags, cfg: ModelConfig, quant: QuantConfig):
    def qc(x, w, flag, seed, stride=1):
        return qconv2d(x, w, seed=jnp.uint32(seed), flag=flag,
                       strides=(stride, stride), padding="SAME",
                       fmt=quant.fmt, q_fwd=quant.quantize_fwd,
                       q_dgrad=quant.quantize_dgrad,
                       q_wgrad=quant.quantize_wgrad,
                       backend=quant.backend)

    li = 0
    x = qc(image, params["stem"]["conv"], qflags[li], 11 * li)
    x = jax.nn.relu(cm.groupnorm(x, params["stem"]["gn"]["scale"],
                                 params["stem"]["gn"]["bias"]))
    for blk in params["blocks"]:
        for lyr in blk["layers"]:
            flag = qflags[li]
            sd = 11 * li
            h = jax.nn.relu(cm.groupnorm(x, lyr["gn1"]["scale"],
                                         lyr["gn1"]["bias"]))
            h = qc(h, lyr["conv1"], flag, sd)
            h = jax.nn.relu(cm.groupnorm(h, lyr["gn2"]["scale"],
                                         lyr["gn2"]["bias"]))
            h = qc(h, lyr["conv2"], flag, sd + 1)
            x = jnp.concatenate([x, h], axis=-1)
            li += 1
        if "transition" in blk:
            flag = qflags[li]
            sd = 11 * li
            t = jax.nn.relu(cm.groupnorm(x, blk["transition"]["gn"]["scale"],
                                         blk["transition"]["gn"]["bias"]))
            t = qc(t, blk["transition"]["conv"], flag, sd)
            x = jax.lax.reduce_window(
                t, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0
            li += 1
    x = jax.nn.relu(cm.groupnorm(x, params["final_gn"]["scale"],
                                 params["final_gn"]["bias"]))
    x = x.mean(axis=(1, 2))
    return x @ params["head"]["w"] + params["head"]["b"]


def loss_fn(params, batch, rng, qflags, cfg: ModelConfig, quant: QuantConfig,
            per_example: bool = False):
    del rng
    logits = forward(params, batch["image"], qflags, cfg, quant)
    return cm.softmax_xent(logits, batch["label"], per_example=per_example)


@register_family("densenet")
def build_densenet(cfg: ModelConfig, quant: QuantConfig) -> Model:
    def batch_spec(batch: int, seq: int = 0):
        s = cfg.image_size
        return {"image": jax.ShapeDtypeStruct((batch, s, s, cfg.in_channels),
                                              jnp.float32),
                "label": jax.ShapeDtypeStruct((batch,), jnp.int32)}

    def batch_axes():
        return {"image": ("batch", None, None, None), "label": ("batch",)}

    return Model(
        config=cfg, quant=quant,
        init=functools.partial(init_params, cfg=cfg),
        param_axes=lambda: param_axes(cfg),
        loss_fn=functools.partial(loss_fn, cfg=cfg, quant=quant),
        batch_spec=batch_spec,
        batch_axes=batch_axes,
        per_example_loss=functools.partial(loss_fn, cfg=cfg, quant=quant,
                                           per_example=True),
        ghost_mask=resnet.conv_ghost_mask,
    )
