"""Encoder-decoder transformer backbone (Whisper-medium assignment).

Per the assignment, the audio frontend (conv + mel) is a STUB: the batch
carries precomputed frame embeddings ``enc_embeds (B, S, d)``.  Sinusoidal
positions on both sides (Whisper-style), MHA (kv = heads), GELU MLP.

DPQuant policy spans encoder + decoder blocks: flags[0:n_enc] gate encoder
blocks, flags[n_enc:] gate decoder blocks.

Serving: ``prefill`` encodes + runs the decoder prompt, caching decoder
self-attention KV and the cross-attention KV (computed once from the encoder
output); ``decode_step`` extends the self cache only.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, QuantConfig
from repro.models import common as cm
from repro.models.registry import Model, register_family
from repro.parallel.axes import logical_constraint as lc


# --------------------------------------------------------------------------- #
# params
# --------------------------------------------------------------------------- #
def _attn_params(key, cfg, n, kv=None):
    d, hp, hd = cfg.d_model, cfg.padded_heads, cfg.head_dim
    kv = kv or cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    pdt = jnp.dtype(cfg.param_dtype)
    return {
        "wq": cm.dense_init(ks[0], (n, d, hp, hd), d, pdt),
        "wk": cm.dense_init(ks[1], (n, d, kv, hd), d, pdt),
        "wv": cm.dense_init(ks[2], (n, d, kv, hd), d, pdt),
        "wo": cm.dense_init(ks[3], (n, hp, hd, d), hp * hd, pdt),
    }


_ATTN_AXES = {
    "wq": ("layers", "embed", "heads", "head_dim"),
    "wk": ("layers", "embed", "kv_heads", "head_dim"),
    "wv": ("layers", "embed", "kv_heads", "head_dim"),
    "wo": ("layers", "heads", "head_dim", "embed"),
}


def _mlp_params(key, cfg, n):
    d, f = cfg.d_model, cfg.d_ff
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 2)
    return {
        "wi": cm.dense_init(ks[0], (n, d, f), d, pdt),
        "wo_mlp": cm.dense_init(ks[1], (n, f, d), f, pdt),
    }


_MLP_AXES = {"wi": ("layers", "embed", "mlp"),
             "wo_mlp": ("layers", "mlp", "embed")}


def init_params(key, cfg: ModelConfig):
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    ne, nd = cfg.n_enc_layers, cfg.n_dec_layers
    enc = {"attn_norm": jnp.zeros((ne, cfg.d_model), pdt),
           "mlp_norm": jnp.zeros((ne, cfg.d_model), pdt),
           **_attn_params(ks[0], cfg, ne), **_mlp_params(ks[1], cfg, ne)}
    dec = {"self_norm": jnp.zeros((nd, cfg.d_model), pdt),
           "cross_norm": jnp.zeros((nd, cfg.d_model), pdt),
           "mlp_norm": jnp.zeros((nd, cfg.d_model), pdt),
           **{f"self_{k}": v for k, v in _attn_params(ks[2], cfg, nd).items()},
           **{f"cross_{k}": v for k, v in _attn_params(ks[3], cfg, nd).items()},
           **_mlp_params(ks[4], cfg, nd)}
    return {
        "embed": cm.embed_init(ks[5], (cfg.padded_vocab, cfg.d_model), pdt),
        "enc_norm": jnp.zeros((cfg.d_model,), pdt),
        "final_norm": jnp.zeros((cfg.d_model,), pdt),
        "enc": enc,
        "dec": dec,
    }


def param_axes(cfg: ModelConfig):
    enc = {"attn_norm": ("layers", "embed"), "mlp_norm": ("layers", "embed"),
           **_ATTN_AXES, **_MLP_AXES}
    dec = {"self_norm": ("layers", "embed"), "cross_norm": ("layers", "embed"),
           "mlp_norm": ("layers", "embed"),
           **{f"self_{k}": v for k, v in _ATTN_AXES.items()},
           **{f"cross_{k}": v for k, v in _ATTN_AXES.items()},
           **_MLP_AXES}
    return {"embed": ("vocab", "embed"), "enc_norm": ("embed",),
            "final_norm": ("embed",), "enc": enc, "dec": dec}


# --------------------------------------------------------------------------- #
# blocks
# --------------------------------------------------------------------------- #
def _mha(h, prm, prefix, flag, seed, cfg, quant, kv_h=None, causal=False,
         chunk_q=None):
    """Self attention over h; returns (out, (k, v))."""
    qp = functools.partial(cm.qproj, quant_cfg=quant, flag=flag)
    cd = h.dtype
    g = lambda k: prm[f"{prefix}{k}"] if prefix else prm[k]
    q = qp("bsd,dhk->bshk", h, g("wq").astype(cd), seed=seed)
    src = kv_h if kv_h is not None else h
    k = qp("bsd,dhk->bshk", src, g("wk").astype(cd), seed=seed + 1)
    v = qp("bsd,dhk->bshk", src, g("wv").astype(cd), seed=seed + 2)
    n_rep = cfg.padded_heads // k.shape[2]
    out = cm.chunked_causal_attention(
        q, cm.repeat_kv(k, n_rep), cm.repeat_kv(v, n_rep),
        chunk_q=chunk_q or cfg.attn_chunk_q, causal=causal,
        scale=1.0 / math.sqrt(cfg.head_dim))
    res = qp("bshk,hkd->bsd", out, g("wo").astype(cd), seed=seed + 3)
    return res, (k, v)


def _mlp(h, prm, flag, seed, cfg, quant):
    qp = functools.partial(cm.qproj, quant_cfg=quant, flag=flag)
    cd = h.dtype
    a = jax.nn.gelu(qp("bsd,df->bsf", h, prm["wi"].astype(cd), seed=seed + 4))
    a = lc(a, "batch", "seq", "mlp")
    return qp("bsf,fd->bsd", a, prm["wo_mlp"].astype(cd), seed=seed + 5)


def encode(params, enc_embeds, qflags, cfg: ModelConfig, quant: QuantConfig):
    cd = jnp.dtype(cfg.compute_dtype)
    B, S, _ = enc_embeds.shape
    x = enc_embeds.astype(cd) + cm.sinusoidal_positions(
        S, cfg.d_model).astype(cd)[None]
    x = lc(x, "batch", "seq", "embed")

    def block(carry, blk, flag, lidx):
        seed = lidx.astype(jnp.uint32) * jnp.uint32(97)
        h = cm.rmsnorm(carry, blk["attn_norm"]).astype(cd)
        a, _ = _mha(h, blk, "", flag, seed, cfg, quant, causal=False)
        carry = carry + a
        h2 = cm.rmsnorm(carry, blk["mlp_norm"]).astype(cd)
        return carry + _mlp(h2, blk, flag, seed, cfg, quant)

    if cfg.remat:
        block = jax.checkpoint(block)

    def body(carry, xs):
        blk, flag, lidx = xs
        return block(carry, blk, flag, lidx), None

    x, _ = jax.lax.scan(body, x, (params["enc"],
                                  qflags[: cfg.n_enc_layers],
                                  jnp.arange(cfg.n_enc_layers)))
    return cm.rmsnorm(x, params["enc_norm"])


def decode_train(params, tokens, enc_out, qflags, cfg, quant):
    cd = jnp.dtype(cfg.compute_dtype)
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    x = x + cm.sinusoidal_positions(S, cfg.d_model).astype(cd)[None]
    x = lc(x, "batch", "seq", "embed")
    dec_flags = qflags[cfg.n_enc_layers:]

    def block(carry, blk, flag, lidx):
        seed = (lidx.astype(jnp.uint32) + jnp.uint32(1000)) * jnp.uint32(97)
        h = cm.rmsnorm(carry, blk["self_norm"]).astype(cd)
        a, _ = _mha(h, blk, "self_", flag, seed, cfg, quant, causal=True)
        carry = carry + a
        h2 = cm.rmsnorm(carry, blk["cross_norm"]).astype(cd)
        c, _ = _mha(h2, blk, "cross_", flag, seed + 10, cfg, quant,
                    kv_h=enc_out, causal=False)
        carry = carry + c
        h3 = cm.rmsnorm(carry, blk["mlp_norm"]).astype(cd)
        return carry + _mlp(h3, blk, flag, seed, cfg, quant)

    if cfg.remat:
        block = jax.checkpoint(block)

    def body(carry, xs):
        blk, flag, lidx = xs
        return block(carry, blk, flag, lidx), None

    x, _ = jax.lax.scan(body, x, (params["dec"], dec_flags,
                                  jnp.arange(cfg.n_dec_layers)))
    return cm.rmsnorm(x, params["final_norm"])


def loss_fn(params, batch, rng, qflags, cfg: ModelConfig, quant: QuantConfig):
    del rng
    enc_out = encode(params, batch["enc_embeds"], qflags, cfg, quant)
    h = decode_train(params, batch["tokens"], enc_out, qflags, cfg, quant)
    return cm.chunked_lm_loss(h[:, :-1], batch["tokens"][:, 1:],
                              params["embed"], real_vocab=cfg.vocab_size,
                              ce_chunk=cfg.ce_chunk)


# --------------------------------------------------------------------------- #
# serving
# --------------------------------------------------------------------------- #
def cache_spec(cfg: ModelConfig, batch: int, seq_len: int):
    cd = jnp.dtype(cfg.compute_dtype)
    nd, kv, hd = cfg.n_dec_layers, cfg.n_kv_heads, cfg.head_dim
    return {
        "self_k": jax.ShapeDtypeStruct((nd, batch, kv, seq_len, hd), cd),
        "self_v": jax.ShapeDtypeStruct((nd, batch, kv, seq_len, hd), cd),
        "cross_k": jax.ShapeDtypeStruct((nd, batch, kv, seq_len, hd), cd),
        "cross_v": jax.ShapeDtypeStruct((nd, batch, kv, seq_len, hd), cd),
        "enc_len": jax.ShapeDtypeStruct((), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_axes(cfg: ModelConfig):
    kvax = ("layers", "batch", "kv_heads", "kv_seq", "head_dim")
    return {"self_k": kvax, "self_v": kvax, "cross_k": kvax,
            "cross_v": kvax, "enc_len": None, "pos": None}


def prefill(params, batch, cfg: ModelConfig, quant: QuantConfig,
            cache_len=None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache_len = cache_len or S
    cd = jnp.dtype(cfg.compute_dtype)
    qflags = jnp.zeros((cfg.n_enc_layers + cfg.n_dec_layers,), jnp.float32)
    enc_out = encode(params, batch["enc_embeds"], qflags, cfg, quant)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    x = x + cm.sinusoidal_positions(S, cfg.d_model).astype(cd)[None]

    def body(carry, xs):
        blk, lidx = xs
        seed = (lidx.astype(jnp.uint32) + jnp.uint32(1000)) * jnp.uint32(97)
        zf = jnp.float32(0.0)
        h = cm.rmsnorm(carry, blk["self_norm"]).astype(cd)
        a, (sk, sv) = _mha(h, blk, "self_", zf, seed, cfg, quant, causal=True)
        carry = carry + a
        h2 = cm.rmsnorm(carry, blk["cross_norm"]).astype(cd)
        c, (ck, cv) = _mha(h2, blk, "cross_", zf, seed + 10, cfg, quant,
                           kv_h=enc_out, causal=False)
        carry = carry + c
        h3 = cm.rmsnorm(carry, blk["mlp_norm"]).astype(cd)
        carry = carry + _mlp(h3, blk, zf, seed, cfg, quant)

        def to_cache(t, n):
            t = jnp.transpose(t, (0, 2, 1, 3))
            if n > t.shape[2]:
                t = jnp.pad(t, [(0, 0), (0, 0), (0, n - t.shape[2]), (0, 0)])
            return t

        return carry, (to_cache(sk, cache_len), to_cache(sv, cache_len),
                       to_cache(ck, cache_len), to_cache(cv, cache_len))

    x, (sks, svs, cks, cvs) = jax.lax.scan(
        body, x, (params["dec"], jnp.arange(cfg.n_dec_layers)))
    h_last = cm.rmsnorm(x[:, -1], params["final_norm"]).astype(jnp.float32)
    logits = jnp.einsum("bd,vd->bv", h_last,
                        params["embed"].astype(jnp.float32))
    cache = {"self_k": sks, "self_v": svs, "cross_k": cks, "cross_v": cvs,
             "enc_len": jnp.asarray(batch["enc_embeds"].shape[1], jnp.int32),
             "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


def _sinusoidal_at(pos, d_model):
    """Sinusoidal position embedding at a (traced) scalar position."""
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10_000.0, 2 * dim / d_model)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def decode_step(params, cache, token, cfg: ModelConfig, quant: QuantConfig):
    from repro.models.transformer import decode_attend
    cd = jnp.dtype(cfg.compute_dtype)
    B = token.shape[0]
    pos = cache["pos"]
    x = jnp.take(params["embed"], token, axis=0).astype(cd)
    x = x + _sinusoidal_at(pos, cfg.d_model).astype(cd)

    def body(carry, xs):
        blk, sk, sv, ck, cv = xs
        h = cm.rmsnorm(carry, blk["self_norm"]).astype(cd)
        q = jnp.einsum("bd,dhk->bhk", h, blk["self_wq"].astype(cd))
        k = jnp.einsum("bd,dhk->bhk", h, blk["self_wk"].astype(cd))
        v = jnp.einsum("bd,dhk->bhk", h, blk["self_wv"].astype(cd))
        sk = jax.lax.dynamic_update_slice(
            sk, k[:, :, None, :].astype(sk.dtype), (0, 0, pos, 0))
        sv = jax.lax.dynamic_update_slice(
            sv, v[:, :, None, :].astype(sv.dtype), (0, 0, pos, 0))
        ctx = decode_attend(q, sk, sv, pos, cfg)
        carry = carry + jnp.einsum("bhk,hkd->bd", ctx.astype(cd),
                                   blk["self_wo"].astype(cd))
        h2 = cm.rmsnorm(carry, blk["cross_norm"]).astype(cd)
        q2 = jnp.einsum("bd,dhk->bhk", h2, blk["cross_wq"].astype(cd))
        ctx2 = decode_attend(q2, ck, cv, cache["enc_len"] - 1, cfg)
        carry = carry + jnp.einsum("bhk,hkd->bd", ctx2.astype(cd),
                                   blk["cross_wo"].astype(cd))
        h3 = cm.rmsnorm(carry, blk["mlp_norm"]).astype(cd)
        a = jax.nn.gelu(jnp.einsum("bd,df->bf", h3, blk["wi"].astype(cd)))
        carry = carry + jnp.einsum("bf,fd->bd", a, blk["wo_mlp"].astype(cd))
        return carry, (sk, sv)

    x, (sks, svs) = jax.lax.scan(
        body, x, (params["dec"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    h_last = cm.rmsnorm(x, params["final_norm"]).astype(jnp.float32)
    logits = jnp.einsum("bd,vd->bv", h_last,
                        params["embed"].astype(jnp.float32))
    new_cache = dict(cache, self_k=sks, self_v=svs, pos=pos + 1)
    return logits, new_cache


@register_family("encdec")
def build_encdec(cfg: ModelConfig, quant: QuantConfig) -> Model:
    def batch_spec(batch: int, seq: int):
        return {
            "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "enc_embeds": jax.ShapeDtypeStruct(
                (batch, seq, cfg.d_model), jnp.dtype(cfg.compute_dtype)),
        }

    def batch_axes():
        return {"tokens": ("batch", "seq"),
                "enc_embeds": ("batch", "seq", "embed")}

    return Model(
        config=cfg, quant=quant,
        init=functools.partial(init_params, cfg=cfg),
        param_axes=lambda: param_axes(cfg),
        loss_fn=functools.partial(loss_fn, cfg=cfg, quant=quant),
        batch_spec=batch_spec,
        batch_axes=batch_axes,
        prefill=functools.partial(prefill, cfg=cfg, quant=quant),
        decode_step=functools.partial(decode_step, cfg=cfg, quant=quant),
        cache_spec=functools.partial(cache_spec, cfg),
        cache_axes=lambda: cache_axes(cfg),
    )
