"""Griffin / RecurrentGemma hybrid: RG-LRU recurrent blocks + local attention.

Layer pattern 1:2 attention:recurrent — (rec, rec, local-attn) repeating.
To keep ``lax.scan`` homogeneous with heterogeneous mixers, layers are
grouped into *superblocks* of one pattern period (scanned), plus an unrolled
recurrent tail when depth % period != 0 (38 = 12*3 + 2 for the 9b config).

RG-LRU (Griffin, De et al. 2024):
    r_t = sigmoid(y_t @ W_a);  i_t = sigmoid(y_t @ W_x)
    log a_t = -c * softplus(Lambda) * r_t           (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * y_t)
Training/prefill evaluates the input-dependent linear recurrence with
``jax.lax.associative_scan`` (log-depth, TPU-friendly); decode is O(1).
The recurrence is elementwise, so it shards perfectly over the ``model`` axis
(lru width dim) with zero collectives; only the projections communicate.

Deviation note: we use dense gate matrices W_a/W_x (the paper uses
block-diagonal); parameter count is higher but the schedule/semantics are
identical.  DPQuant quantizes all projections; the elementwise recurrence
stays fp32 (DESIGN.md §4).

Local attention: MQA (kv=1), RoPE, sliding window; decode uses a ring cache
of ``window`` entries — total cache is O(window + lru_width) per layer,
which is what makes the ``long_500k`` cell runnable.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, QuantConfig
from repro.models import common as cm
from repro.models.mamba2 import _causal_conv
from repro.models.registry import Model, register_family
from repro.parallel.axes import logical_constraint as lc

C_RGLRU = 8.0


# --------------------------------------------------------------------------- #
# params
# --------------------------------------------------------------------------- #
def _init_rec(key, cfg: ModelConfig, n: int):
    d, W = cfg.d_model, cfg.lru_width
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    return {
        "norm": jnp.zeros((n, d), pdt),
        "w_x": cm.dense_init(ks[0], (n, d, W), d, pdt),
        "w_gate": cm.dense_init(ks[1], (n, d, W), d, pdt),
        "conv_w": cm.dense_init(ks[2], (n, cfg.conv_width, W),
                                cfg.conv_width, pdt),
        "conv_b": jnp.zeros((n, W), pdt),
        "gate_a": cm.dense_init(ks[3], (n, W, W), W, pdt),
        "gate_x": cm.dense_init(ks[4], (n, W, W), W, pdt),
        "lam": jnp.broadcast_to(jnp.linspace(-2.0, 2.0, W),
                                (n, W)).astype(jnp.float32),
        "w_out": cm.dense_init(ks[5], (n, W, d), W, pdt),
    }


_REC_AXES = {
    "norm": ("layers", "embed"),
    "w_x": ("layers", "embed", "mlp"),
    "w_gate": ("layers", "embed", "mlp"),
    "conv_w": ("layers", "conv", "mlp"),
    "conv_b": ("layers", "mlp"),
    "gate_a": ("layers", None, "mlp"),
    "gate_x": ("layers", None, "mlp"),
    "lam": ("layers", "mlp"),
    "w_out": ("layers", "mlp", "embed"),
}


def _init_attn(key, cfg: ModelConfig, n: int):
    d, hp, hd = cfg.d_model, cfg.padded_heads, cfg.head_dim
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "norm": jnp.zeros((n, d), pdt),
        "wq": cm.dense_init(ks[0], (n, d, hp, hd), d, pdt),
        "wk": cm.dense_init(ks[1], (n, d, 1, hd), d, pdt),
        "wv": cm.dense_init(ks[2], (n, d, 1, hd), d, pdt),
        "wo": cm.dense_init(ks[3], (n, hp, hd, d), hp * hd, pdt),
    }


_ATTN_AXES = {
    "norm": ("layers", "embed"),
    "wq": ("layers", "embed", "heads", "head_dim"),
    "wk": ("layers", "embed", "kv_heads", "head_dim"),
    "wv": ("layers", "embed", "kv_heads", "head_dim"),
    "wo": ("layers", "heads", "head_dim", "embed"),
}


def _init_mlp(key, cfg: ModelConfig, n: int):
    d, f = cfg.d_model, cfg.d_ff
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "mlp_norm": jnp.zeros((n, d), pdt),
        "wi_gate": cm.dense_init(ks[0], (n, d, f), d, pdt),
        "wi_up": cm.dense_init(ks[1], (n, d, f), d, pdt),
        "wo_mlp": cm.dense_init(ks[2], (n, f, d), f, pdt),
    }


_MLP_AXES = {
    "mlp_norm": ("layers", "embed"),
    "wi_gate": ("layers", "embed", "mlp"),
    "wi_up": ("layers", "embed", "mlp"),
    "wo_mlp": ("layers", "mlp", "embed"),
}


def _layout(cfg: ModelConfig):
    period = len(cfg.block_pattern) or 3
    n_super = cfg.n_layers // period
    n_tail = cfg.n_layers - n_super * period
    return period, n_super, n_tail


def init_params(key, cfg: ModelConfig):
    pdt = jnp.dtype(cfg.param_dtype)
    period, n_super, n_tail = _layout(cfg)
    ks = jax.random.split(key, 10)
    sb = {
        "rec1": {**_init_rec(ks[0], cfg, n_super), **_init_mlp(ks[1], cfg, n_super)},
        "rec2": {**_init_rec(ks[2], cfg, n_super), **_init_mlp(ks[3], cfg, n_super)},
        "attn": {**_init_attn(ks[4], cfg, n_super), **_init_mlp(ks[5], cfg, n_super)},
    }
    params = {
        "embed": cm.embed_init(ks[6], (cfg.padded_vocab, cfg.d_model), pdt),
        "final_norm": jnp.zeros((cfg.d_model,), pdt),
        "superblocks": sb,
    }
    if n_tail:
        params["tail"] = {**_init_rec(ks[7], cfg, n_tail),
                          **_init_mlp(ks[8], cfg, n_tail)}
    return params


def param_axes(cfg: ModelConfig):
    _, _, n_tail = _layout(cfg)
    rec_axes = {**_REC_AXES, **_MLP_AXES}
    axes = {
        "embed": ("vocab", "embed"),
        "final_norm": ("embed",),
        "superblocks": {
            "rec1": dict(rec_axes),
            "rec2": dict(rec_axes),
            "attn": {**_ATTN_AXES, **_MLP_AXES},
        },
    }
    if n_tail:
        axes["tail"] = dict(rec_axes)
    return axes


# --------------------------------------------------------------------------- #
# RG-LRU
# --------------------------------------------------------------------------- #
def rglru_scan(log_a, inp, h0=None):
    """h_t = exp(log_a_t) * h_{t-1} + inp_t along axis 1 (S)."""
    a = jnp.exp(log_a)
    if h0 is not None:
        inp = inp.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, inp), axis=1)
    return h


def rec_mixer(x, prm, flag, seed, cfg: ModelConfig, quant: QuantConfig,
              conv_state=None, h0=None):
    qp = functools.partial(cm.qproj, quant_cfg=quant, flag=flag)
    cd = x.dtype
    y = cm.rmsnorm(x, prm["norm"]).astype(cd)
    xb = qp("bsd,dw->bsw", y, prm["w_x"].astype(cd), seed=seed)
    gate = qp("bsd,dw->bsw", y, prm["w_gate"].astype(cd), seed=seed + 1)
    xb, new_conv = _causal_conv(xb, prm["conv_w"], prm["conv_b"],
                                state=conv_state, activation=None)
    xb = lc(xb, "batch", "seq", "mlp")
    r = jax.nn.sigmoid(qp("bsw,wu->bsu", xb, prm["gate_a"].astype(cd),
                          seed=seed + 2).astype(jnp.float32))
    i = jax.nn.sigmoid(qp("bsw,wu->bsu", xb, prm["gate_x"].astype(cd),
                          seed=seed + 3).astype(jnp.float32))
    log_a = -C_RGLRU * jax.nn.softplus(prm["lam"])[None, None, :] * r
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    inp = mult * i * xb.astype(jnp.float32)
    h = rglru_scan(log_a, inp, h0=h0)
    out = (h.astype(cd)) * jax.nn.gelu(gate)
    res = qp("bsw,wd->bsd", out, prm["w_out"].astype(cd), seed=seed + 4)
    return res, (new_conv, h[:, -1])


def attn_mixer(x, prm, flag, seed, positions, cfg: ModelConfig,
               quant: QuantConfig):
    qp = functools.partial(cm.qproj, quant_cfg=quant, flag=flag)
    cd = x.dtype
    h = cm.rmsnorm(x, prm["norm"]).astype(cd)
    q = qp("bsd,dhk->bshk", h, prm["wq"].astype(cd), seed=seed)
    k = qp("bsd,dhk->bshk", h, prm["wk"].astype(cd), seed=seed + 1)
    v = qp("bsd,dhk->bshk", h, prm["wv"].astype(cd), seed=seed + 2)
    q = cm.rope(q, positions, cfg.rope_theta)
    k = cm.rope(k, positions, cfg.rope_theta)
    kr = cm.repeat_kv(k, cfg.padded_heads)
    vr = cm.repeat_kv(v, cfg.padded_heads)
    out = cm.chunked_causal_attention(
        q, kr, vr, chunk_q=cfg.attn_chunk_q, causal=True,
        window=cfg.attn_window, scale=1.0 / math.sqrt(cfg.head_dim))
    res = qp("bshk,hkd->bsd", out, prm["wo"].astype(cd), seed=seed + 3)
    return res, (k, v)


def mlp(x, prm, flag, seed, cfg: ModelConfig, quant: QuantConfig):
    qp = functools.partial(cm.qproj, quant_cfg=quant, flag=flag)
    cd = x.dtype
    h = cm.rmsnorm(x, prm["mlp_norm"]).astype(cd)
    g = qp("bsd,df->bsf", h, prm["wi_gate"].astype(cd), seed=seed + 5)
    u = qp("bsd,df->bsf", h, prm["wi_up"].astype(cd), seed=seed + 6)
    return qp("bsf,fd->bsd", jax.nn.gelu(g) * u, prm["wo_mlp"].astype(cd),
              seed=seed + 7)


# --------------------------------------------------------------------------- #
# forward
# --------------------------------------------------------------------------- #
def forward_hidden(params, tokens, qflags, cfg: ModelConfig,
                   quant: QuantConfig):
    cd = jnp.dtype(cfg.compute_dtype)
    period, n_super, n_tail = _layout(cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), cd)
    x = lc(x, "batch", "seq", "embed")
    positions = jnp.arange(tokens.shape[1])[None, :]
    flags_sb = qflags[: n_super * period].reshape(n_super, period)

    def superblock(carry, sb, flags, sidx):
        seed = sidx.astype(jnp.uint32) * jnp.uint32(397)
        r1, _ = rec_mixer(carry, sb["rec1"], flags[0], seed, cfg, quant)
        carry = carry + r1
        carry = carry + mlp(carry, sb["rec1"], flags[0], seed, cfg, quant)
        r2, _ = rec_mixer(carry, sb["rec2"], flags[1], seed + 11, cfg, quant)
        carry = carry + r2
        carry = carry + mlp(carry, sb["rec2"], flags[1], seed + 11, cfg, quant)
        a, _ = attn_mixer(carry, sb["attn"], flags[2], seed + 23, positions,
                          cfg, quant)
        carry = carry + a
        carry = carry + mlp(carry, sb["attn"], flags[2], seed + 23, cfg, quant)
        return carry

    if cfg.remat:
        superblock = jax.checkpoint(superblock)

    def body(carry, xs):
        sb, flags, sidx = xs
        return superblock(carry, sb, flags, sidx), None

    x, _ = jax.lax.scan(
        body, x, (params["superblocks"], flags_sb, jnp.arange(n_super)))

    if n_tail:
        flags_tail = qflags[n_super * period:]

        def tail_body(carry, xs):
            prm, flag, tidx = xs
            seed = (jnp.uint32(1_000_003)
                    + tidx.astype(jnp.uint32) * jnp.uint32(397))
            r, _ = rec_mixer(carry, prm, flag, seed, cfg, quant)
            carry = carry + r
            carry = carry + mlp(carry, prm, flag, seed, cfg, quant)
            return carry, None

        x, _ = jax.lax.scan(
            tail_body, x, (params["tail"], flags_tail, jnp.arange(n_tail)))
    return cm.rmsnorm(x, params["final_norm"])


def lm_loss(params, batch, rng, qflags, cfg: ModelConfig, quant: QuantConfig):
    del rng
    tokens = batch["tokens"]
    h = forward_hidden(params, tokens, qflags, cfg, quant)
    return cm.chunked_lm_loss(h[:, :-1], tokens[:, 1:], params["embed"],
                              real_vocab=cfg.vocab_size, ce_chunk=cfg.ce_chunk)


# --------------------------------------------------------------------------- #
# serving
# --------------------------------------------------------------------------- #
def cache_spec(cfg: ModelConfig, batch: int, seq_len: int):
    cd = jnp.dtype(cfg.compute_dtype)
    period, n_super, n_tail = _layout(cfg)
    W = cfg.lru_width
    win = min(cfg.attn_window, seq_len)
    cw = cfg.conv_width - 1

    def rec_state(n):
        return {"h": jax.ShapeDtypeStruct((n, batch, W), jnp.float32),
                "conv": jax.ShapeDtypeStruct((n, batch, cw, W), cd)}

    spec = {
        "rec1": rec_state(n_super),
        "rec2": rec_state(n_super),
        "attn": {
            "k": jax.ShapeDtypeStruct((n_super, batch, 1, win, cfg.head_dim), cd),
            "v": jax.ShapeDtypeStruct((n_super, batch, 1, win, cfg.head_dim), cd),
        },
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if n_tail:
        spec["tail"] = rec_state(n_tail)
    return spec


def cache_axes(cfg: ModelConfig):
    _, _, n_tail = _layout(cfg)

    def rec_axes():
        return {"h": ("layers", "batch", "mlp"),
                "conv": ("layers", "batch", None, "mlp")}

    axes = {
        "rec1": rec_axes(), "rec2": rec_axes(),
        "attn": {"k": ("layers", "batch", "kv_heads", "kv_seq", "head_dim"),
                 "v": ("layers", "batch", "kv_heads", "kv_seq", "head_dim")},
        "pos": None,
    }
    if n_tail:
        axes["tail"] = rec_axes()
    return axes


def prefill(params, batch, cfg: ModelConfig, quant: QuantConfig,
            cache_len=None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    cd = jnp.dtype(cfg.compute_dtype)
    period, n_super, n_tail = _layout(cfg)
    win = min(cfg.attn_window, cache_len or S)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), cd)
    positions = jnp.arange(S)[None, :]
    zf = jnp.zeros((period,), jnp.float32)

    def sb_body(carry, xs):
        sb, sidx = xs
        seed = sidx.astype(jnp.uint32) * jnp.uint32(397)
        r1, st1 = rec_mixer(carry, sb["rec1"], zf[0], seed, cfg, quant)
        carry = carry + r1
        carry = carry + mlp(carry, sb["rec1"], zf[0], seed, cfg, quant)
        r2, st2 = rec_mixer(carry, sb["rec2"], zf[1], seed + 11, cfg, quant)
        carry = carry + r2
        carry = carry + mlp(carry, sb["rec2"], zf[1], seed + 11, cfg, quant)
        a, (k, v) = attn_mixer(carry, sb["attn"], zf[2], seed + 23, positions,
                               cfg, quant)
        carry = carry + a
        carry = carry + mlp(carry, sb["attn"], zf[2], seed + 23, cfg, quant)
        # ring cache = last `win` positions (slot = pos % win aligns when
        # S % win == 0; otherwise roll)
        kc = jnp.transpose(k[:, -win:], (0, 2, 1, 3))
        vc = jnp.transpose(v[:, -win:], (0, 2, 1, 3))
        shift = S % win
        if shift:
            kc = jnp.roll(kc, shift, axis=2)
            vc = jnp.roll(vc, shift, axis=2)
        ys = ({"h": st1[1], "conv": st1[0]},
              {"h": st2[1], "conv": st2[0]},
              {"k": kc, "v": vc})
        return carry, ys

    x, (st_r1, st_r2, st_attn) = jax.lax.scan(
        sb_body, x, (params["superblocks"], jnp.arange(n_super)))

    cache = {"rec1": st_r1, "rec2": st_r2, "attn": st_attn,
             "pos": jnp.asarray(S, jnp.int32)}

    if n_tail:
        def tail_body(carry, xs):
            prm, tidx = xs
            seed = (jnp.uint32(1_000_003)
                    + tidx.astype(jnp.uint32) * jnp.uint32(397))
            r, st = rec_mixer(carry, prm, zf[0], seed, cfg, quant)
            carry = carry + r
            carry = carry + mlp(carry, prm, zf[0], seed, cfg, quant)
            return carry, {"h": st[1], "conv": st[0]}

        x, st_tail = jax.lax.scan(tail_body, x,
                                  (params["tail"], jnp.arange(n_tail)))
        cache["tail"] = st_tail

    h_last = cm.rmsnorm(x[:, -1], params["final_norm"]).astype(jnp.float32)
    logits = jnp.einsum("bd,vd->bv", h_last,
                        params["embed"].astype(jnp.float32))
    return logits, cache


def _rec_decode(x, prm, st, cfg, cd):
    """One-token RG-LRU update. x: (B, d)."""
    y = cm.rmsnorm(x, prm["norm"]).astype(cd)
    xb = jnp.einsum("bd,dw->bw", y, prm["w_x"].astype(cd))
    gate = jnp.einsum("bd,dw->bw", y, prm["w_gate"].astype(cd))
    xw = jnp.concatenate([st["conv"].astype(cd), xb[:, None, :]], axis=1)
    xb = jnp.einsum("bwd,wd->bd", xw, prm["conv_w"].astype(cd)) \
        + prm["conv_b"][None, :]
    new_conv = xw[:, 1:]
    r = jax.nn.sigmoid(jnp.einsum("bw,wu->bu", xb,
                                  prm["gate_a"].astype(cd)).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bw,wu->bu", xb,
                                  prm["gate_x"].astype(cd)).astype(jnp.float32))
    log_a = -C_RGLRU * jax.nn.softplus(prm["lam"])[None, :] * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    h = a * st["h"] + mult * i * xb.astype(jnp.float32)
    out = h.astype(cd) * jax.nn.gelu(gate)
    res = jnp.einsum("bw,wd->bd", out, prm["w_out"].astype(cd))
    return res, {"h": h, "conv": new_conv}


def _mlp_decode(x, prm, cd):
    h = cm.rmsnorm(x, prm["mlp_norm"]).astype(cd)
    g = jnp.einsum("bd,df->bf", h, prm["wi_gate"].astype(cd))
    u = jnp.einsum("bd,df->bf", h, prm["wi_up"].astype(cd))
    return jnp.einsum("bf,fd->bd", jax.nn.gelu(g) * u,
                      prm["wo_mlp"].astype(cd))


def decode_step(params, cache, token, cfg: ModelConfig, quant: QuantConfig):
    cd = jnp.dtype(cfg.compute_dtype)
    B = token.shape[0]
    period, n_super, n_tail = _layout(cfg)
    pos = cache["pos"]
    win = cache["attn"]["k"].shape[3]
    slot = jnp.mod(pos, win)
    x = jnp.take(params["embed"], token, axis=0).astype(cd)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), cd)
    positions = jnp.full((B, 1), pos, jnp.int32)

    def sb_body(carry, xs):
        sb, st1, st2, sta = xs
        r1, nst1 = _rec_decode(carry, sb["rec1"], st1, cfg, cd)
        carry = carry + r1
        carry = carry + _mlp_decode(carry, sb["rec1"], cd)
        r2, nst2 = _rec_decode(carry, sb["rec2"], st2, cfg, cd)
        carry = carry + r2
        carry = carry + _mlp_decode(carry, sb["rec2"], cd)
        # windowed MQA decode
        h = cm.rmsnorm(carry, sb["attn"]["norm"]).astype(cd)
        q = jnp.einsum("bd,dhk->bhk", h, sb["attn"]["wq"].astype(cd))
        k = jnp.einsum("bd,dhk->bhk", h, sb["attn"]["wk"].astype(cd))
        v = jnp.einsum("bd,dhk->bhk", h, sb["attn"]["wv"].astype(cd))
        q = cm.rope(q[:, None], positions, cfg.rope_theta)[:, 0]
        k = cm.rope(k[:, None], positions, cfg.rope_theta)[:, 0]
        kc = jax.lax.dynamic_update_slice(
            sta["k"], k[:, :, None, :].astype(cd), (0, 0, slot, 0))
        vc = jax.lax.dynamic_update_slice(
            sta["v"], v[:, :, None, :].astype(cd), (0, 0, slot, 0))
        # slot j holds absolute position p = pos - ((pos - j) mod win)
        j = jnp.arange(win)
        stored = pos - jnp.mod(pos - j, win)
        valid = stored >= jnp.maximum(0, pos - win + 1)
        scores = jnp.einsum("bhk,bgsk->bhs", q.astype(jnp.float32),
                            kc.astype(jnp.float32)) / math.sqrt(cfg.head_dim)
        scores = jnp.where(valid[None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhs,bgsk->bhk", probs.astype(cd), vc)
        a = jnp.einsum("bhk,hkd->bd", ctx, sb["attn"]["wo"].astype(cd))
        carry = carry + a
        carry = carry + _mlp_decode(carry, sb["attn"], cd)
        return carry, (nst1, nst2, {"k": kc, "v": vc})

    x, (nst1, nst2, nsta) = jax.lax.scan(
        sb_body, x,
        (params["superblocks"], cache["rec1"], cache["rec2"], cache["attn"]))
    new_cache = {"rec1": nst1, "rec2": nst2, "attn": nsta, "pos": pos + 1}

    if n_tail:
        def tail_body(carry, xs):
            prm, st = xs
            r, nst = _rec_decode(carry, prm, st, cfg, cd)
            carry = carry + r
            carry = carry + _mlp_decode(carry, prm, cd)
            return carry, nst

        x, nst_tail = jax.lax.scan(tail_body, x,
                                   (params["tail"], cache["tail"]))
        new_cache["tail"] = nst_tail

    h_last = cm.rmsnorm(x, params["final_norm"]).astype(jnp.float32)
    logits = jnp.einsum("bd,vd->bv", h_last,
                        params["embed"].astype(jnp.float32))
    return logits, new_cache


@register_family("hybrid")
def build_hybrid(cfg: ModelConfig, quant: QuantConfig) -> Model:
    from repro.models.transformer import _dense_batch_spec, _dense_batch_axes
    return Model(
        config=cfg, quant=quant,
        init=functools.partial(init_params, cfg=cfg),
        param_axes=lambda: param_axes(cfg),
        loss_fn=functools.partial(lm_loss, cfg=cfg, quant=quant),
        batch_spec=_dense_batch_spec(cfg),
        batch_axes=_dense_batch_axes(cfg),
        prefill=functools.partial(prefill, cfg=cfg, quant=quant),
        decode_step=functools.partial(decode_step, cfg=cfg, quant=quant),
        cache_spec=functools.partial(cache_spec, cfg),
        cache_axes=lambda: cache_axes(cfg),
    )
