"""Mamba-2 (SSD — state-space duality) language model.

Chunked SSD algorithm (Dao & Gu 2024, "minimal SSD" formulation):

  * within-chunk: quadratic attention-like term masked by the decay kernel
    ``L[i,j] = exp(cumsum(dA)_i - cumsum(dA)_j)`` (i >= j);
  * cross-chunk: per-chunk end states carried through an O(n_chunks) scan.

This gives exact linear-recurrence semantics with matmul-dominant compute —
the TPU-friendly reformulation (the recurrence itself never runs step-by-step
during training).  Decode is the O(1) state update.

DPQuant applicability (DESIGN.md §4): the in/out projections and the two SSD
contraction GEMMs quantize under the block flag; the elementwise decay math
stays fp32 (no GEMM to quantize).

Shapes: ngroups = 1 (B/C shared across heads), following the 130m config.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, QuantConfig
from repro.models import common as cm
from repro.models.registry import Model, register_family
from repro.parallel.axes import logical_constraint as lc


# --------------------------------------------------------------------------- #
# params
# --------------------------------------------------------------------------- #
def init_params(key, cfg: ModelConfig):
    pdt = jnp.dtype(cfg.param_dtype)
    d, di, H, N = cfg.d_model, cfg.d_inner, cfg.ssm_heads, cfg.ssm_state
    L = cfg.n_layers
    w = cfg.conv_width
    keys = jax.random.split(key, 8)
    # fused in_proj: [z (di), x (di), B (N), C (N), dt (H)]
    proj_out = 2 * di + 2 * N + H
    blocks = {
        "norm": jnp.zeros((L, d), pdt),
        "in_proj": cm.dense_init(keys[0], (L, d, proj_out), d, pdt),
        "conv_w": cm.dense_init(keys[1], (L, w, di), w, pdt),
        "conv_b": jnp.zeros((L, di), pdt),
        "dt_bias": jnp.zeros((L, H), jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.linspace(1.0, 16.0, H), (L, H)).astype(jnp.float32)),
        "D": jnp.ones((L, H), jnp.float32),
        "out_norm": jnp.zeros((L, di), pdt),
        "out_proj": cm.dense_init(keys[2], (L, di, d), di, pdt),
    }
    return {
        "embed": cm.embed_init(keys[3], (cfg.padded_vocab, d), pdt),
        "final_norm": jnp.zeros((d,), pdt),
        "blocks": blocks,
    }


def param_axes(cfg: ModelConfig):
    return {
        "embed": ("vocab", "embed"),
        "final_norm": ("embed",),
        "blocks": {
            "norm": ("layers", "embed"),
            "in_proj": ("layers", "embed", "mlp"),
            "conv_w": ("layers", "conv", "mlp"),
            "conv_b": ("layers", "mlp"),
            "dt_bias": ("layers", "heads"),
            "A_log": ("layers", "heads"),
            "D": ("layers", "heads"),
            "out_norm": ("layers", "mlp"),
            "out_proj": ("layers", "mlp", "embed"),
        },
    }


# --------------------------------------------------------------------------- #
# SSD core
# --------------------------------------------------------------------------- #
def _segsum(a):
    """a: (..., Q) -> (..., Q, Q) lower-tri cumulative sums:
    out[i, j] = sum_{k=j+1..i} a[k] for i >= j, -inf above diagonal."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, flag, seed, quant: QuantConfig):
    """SSD forward. Shapes:
      x:  (b, S, H, P)    inputs per head
      dt: (b, S, H)       positive step sizes
      A:  (H,)            negative decay rates
      B:  (b, S, N)       input maps (ngroups=1)
      C:  (b, S, N)       output maps
    Returns y: (b, S, H, P).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    S_orig = S
    if S % Q != 0:
        # pad tail (dt=0 -> unit decay, x=0 -> no state contribution)
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q

    xr = x.reshape(b, nc, Q, H, P)
    dtr = dt.reshape(b, nc, Q, H)
    Br = B.reshape(b, nc, Q, N)
    Cr = C.reshape(b, nc, Q, N)

    dA = dtr * A[None, None, None, :]                 # (b, nc, Q, H) negative
    dA_cum = jnp.cumsum(dA, axis=2)

    qp = functools.partial(cm.qproj, quant_cfg=quant, flag=flag)

    # ---- within-chunk (quadratic, attention-like) ----
    Lmat = jnp.exp(_segsum(jnp.swapaxes(dA, 2, 3)))   # (b, nc, H, Q, Q)
    CB = qp("bcln,bcsn->bcls", Cr, Br, seed=seed + 30)  # (b, nc, Q, Q)
    gate = CB[:, :, None] * Lmat                       # (b, nc, H, L, S)
    xdt = xr * dtr[..., None]
    y_diag = qp("bchls,bcshp->bclhp",
                gate.astype(xdt.dtype), xdt, seed=seed + 31)

    # ---- per-chunk end states ----
    decay_states = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (b, nc, Q, H)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn",
                        Br.astype(jnp.float32), decay_states.astype(jnp.float32),
                        xdt.astype(jnp.float32))            # (b, nc, H, P, N)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])              # (b, nc, H)

    def scan_fn(carry, inp):
        s_c, g_c = inp                                      # (b,H,P,N), (b,H)
        new = carry * g_c[:, :, None, None] + s_c
        return new, carry                                   # emit state BEFORE chunk

    init = jnp.zeros((b, H, P, N), jnp.float32)
    _, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.swapaxes(states, 0, 1), jnp.swapaxes(chunk_decay, 0, 1)))
    prev_states = jnp.swapaxes(prev_states, 0, 1)           # (b, nc, H, P, N)

    # ---- cross-chunk output ----
    out_decay = jnp.exp(dA_cum)                             # (b, nc, Q, H)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp",
                       Cr.astype(jnp.float32), prev_states,
                       out_decay.astype(jnp.float32))

    y = y_diag.astype(jnp.float32) + y_off
    return y.reshape(b, S, H, P)[:, :S_orig].astype(x.dtype)


def _causal_conv(x, w, b, state=None, activation=jax.nn.silu):
    """Depthwise causal conv. x: (B, S, D); w: (W, D); returns (y, new_state).

    ``state``: (B, W-1, D) trailing context for decode continuity."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else None
    y = y + b[None, None, :]
    if activation is not None:
        y = activation(y)
    return y, new_state


def mamba_block(x, blk, flag, lidx, positions, cfg: ModelConfig,
                quant: QuantConfig, conv_state=None, ssm_state=None):
    """Full Mamba-2 block (train/prefill path). Returns residual output."""
    del positions
    d, di, H, N = cfg.d_model, cfg.d_inner, cfg.ssm_heads, cfg.ssm_state
    P = cfg.ssm_head_dim
    seed = lidx.astype(jnp.uint32) * jnp.uint32(97)
    qp = functools.partial(cm.qproj, quant_cfg=quant, flag=flag)
    cd = x.dtype

    h = cm.rmsnorm(x, blk["norm"]).astype(cd)
    zxbcdt = qp("bsd,de->bse", h, blk["in_proj"].astype(cd), seed=seed)
    z = zxbcdt[..., :di]
    xs = zxbcdt[..., di:2 * di]
    Bc = zxbcdt[..., 2 * di:2 * di + N].astype(jnp.float32)
    Cc = zxbcdt[..., 2 * di + N:2 * di + 2 * N].astype(jnp.float32)
    dt = zxbcdt[..., 2 * di + 2 * N:].astype(jnp.float32)

    xs, new_conv = _causal_conv(xs, blk["conv_w"], blk["conv_b"], conv_state)
    dt = jax.nn.softplus(dt + blk["dt_bias"][None, None, :])
    A = -jnp.exp(blk["A_log"])

    xh = xs.reshape(*xs.shape[:2], H, P)
    y = ssd_chunked(xh, dt, A, Bc, Cc, cfg.ssm_chunk, flag, seed, quant)
    y = y + xh.astype(jnp.float32) * blk["D"][None, None, :, None]
    y = y.reshape(*xs.shape[:2], di).astype(cd)
    # gated RMSNorm (mamba2 style)
    y = cm.rmsnorm(y * jax.nn.silu(z), blk["out_norm"])
    out = qp("bse,ed->bsd", y.astype(cd), blk["out_proj"].astype(cd),
             seed=seed + 1)
    return out, new_conv


def forward_hidden(params, tokens, qflags, cfg: ModelConfig,
                   quant: QuantConfig):
    cd = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    x = lc(x, "batch", "seq", "embed")
    L = cfg.n_layers

    def apply_block(carry, blk, flag, lidx):
        out, _ = mamba_block(carry, blk, flag, lidx, None, cfg, quant)
        return carry + out

    if cfg.remat:
        apply_block = jax.checkpoint(apply_block)

    def body(carry, xs):
        blk, flag, lidx = xs
        return apply_block(carry, blk, flag, lidx), None

    x, _ = jax.lax.scan(body, x, (params["blocks"], qflags, jnp.arange(L)))
    return cm.rmsnorm(x, params["final_norm"])


def lm_loss(params, batch, rng, qflags, cfg: ModelConfig, quant: QuantConfig):
    del rng
    tokens = batch["tokens"]
    h = forward_hidden(params, tokens, qflags, cfg, quant)
    return cm.chunked_lm_loss(h[:, :-1], tokens[:, 1:], params["embed"],
                              real_vocab=cfg.vocab_size, ce_chunk=cfg.ce_chunk)


# --------------------------------------------------------------------------- #
# serving: O(1)-state decode
# --------------------------------------------------------------------------- #
def cache_spec(cfg: ModelConfig, batch: int, seq_len: int):
    del seq_len  # state size is sequence-independent (that's the point)
    L, H, P, N = cfg.n_layers, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    w, di = cfg.conv_width, cfg.d_inner
    return {
        "ssm": jax.ShapeDtypeStruct((L, batch, H, P, N), jnp.float32),
        "conv": jax.ShapeDtypeStruct((L, batch, w - 1, di),
                                     jnp.dtype(cfg.compute_dtype)),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_axes(cfg: ModelConfig):
    return {"ssm": ("layers", "batch", "heads", None, "state"),
            "conv": ("layers", "batch", None, "mlp"),
            "pos": None}


def prefill(params, batch, cfg: ModelConfig, quant: QuantConfig,
            cache_len=None):
    """Run the prompt, produce last-token logits + recurrent state."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    cd = jnp.dtype(cfg.compute_dtype)
    di, H, P, N = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    qflags = jnp.zeros((cfg.n_layers,), jnp.float32)

    def body(carry, xs):
        blk, flag, lidx = xs
        seed = lidx.astype(jnp.uint32) * jnp.uint32(97)
        qp = functools.partial(cm.qproj, quant_cfg=quant, flag=flag)
        h = cm.rmsnorm(carry, blk["norm"]).astype(cd)
        zxbcdt = qp("bsd,de->bse", h, blk["in_proj"].astype(cd), seed=seed)
        z = zxbcdt[..., :di]
        xs_ = zxbcdt[..., di:2 * di]
        Bc = zxbcdt[..., 2 * di:2 * di + N].astype(jnp.float32)
        Cc = zxbcdt[..., 2 * di + N:2 * di + 2 * N].astype(jnp.float32)
        dt = jax.nn.softplus(
            zxbcdt[..., 2 * di + 2 * N:].astype(jnp.float32)
            + blk["dt_bias"][None, None, :])
        xs_, conv_state = _causal_conv(xs_, blk["conv_w"], blk["conv_b"])
        A = -jnp.exp(blk["A_log"])
        xh = xs_.reshape(B, S, H, P)
        y = ssd_chunked(xh, dt, A, Bc, Cc, cfg.ssm_chunk, flag, seed, quant)
        # final ssm state: recompute from full sequence decays
        dA = dt * A[None, None, :]
        dA_cum_total = jnp.cumsum(dA, axis=1)
        decay = jnp.exp(dA_cum_total[:, -1:, :] - dA_cum_total)  # (B,S,H)
        xdt = xh * dt[..., None]
        final_state = jnp.einsum("bsn,bsh,bshp->bhpn",
                                 Bc, decay, xdt.astype(jnp.float32))
        y = y + xh.astype(jnp.float32) * blk["D"][None, None, :, None]
        y = cm.rmsnorm(y.reshape(B, S, di).astype(cd) * jax.nn.silu(z),
                       blk["out_norm"])
        out = qp("bse,ed->bsd", y.astype(cd), blk["out_proj"].astype(cd),
                 seed=seed + 1)
        return carry + out, (final_state, conv_state)

    x, (ssm_states, conv_states) = jax.lax.scan(
        body, x, (params["blocks"], qflags, jnp.arange(cfg.n_layers)))
    h_last = cm.rmsnorm(x[:, -1], params["final_norm"]).astype(jnp.float32)
    logits = jnp.einsum("bd,vd->bv", h_last,
                        params["embed"].astype(jnp.float32))
    cache = {"ssm": ssm_states, "conv": conv_states,
             "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


def decode_step(params, cache, token, cfg: ModelConfig, quant: QuantConfig):
    cd = jnp.dtype(cfg.compute_dtype)
    B = token.shape[0]
    di, H, P, N = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    W = cfg.conv_width
    x = jnp.take(params["embed"], token, axis=0).astype(cd)

    def body(carry, xs):
        blk, ssm, conv = xs                        # ssm (B,H,P,N); conv (B,W-1,di)
        h = cm.rmsnorm(carry, blk["norm"]).astype(cd)
        zxbcdt = jnp.einsum("bd,de->be", h, blk["in_proj"].astype(cd))
        z = zxbcdt[..., :di]
        xs_ = zxbcdt[..., di:2 * di]
        Bc = zxbcdt[..., 2 * di:2 * di + N].astype(jnp.float32)
        Cc = zxbcdt[..., 2 * di + N:2 * di + 2 * N].astype(jnp.float32)
        dt = jax.nn.softplus(zxbcdt[..., 2 * di + 2 * N:].astype(jnp.float32)
                             + blk["dt_bias"][None, :])
        # conv ring update
        xw = jnp.concatenate([conv.astype(cd), xs_[:, None, :]], axis=1)  # (B,W,di)
        y_conv = jnp.einsum("bwd,wd->bd", xw, blk["conv_w"].astype(cd))
        xs_ = jax.nn.silu(y_conv + blk["conv_b"][None, :])
        new_conv = xw[:, 1:]
        # state update
        A = -jnp.exp(blk["A_log"])
        dA = jnp.exp(dt * A[None, :])                              # (B,H)
        xh = xs_.reshape(B, H, P).astype(jnp.float32)
        new_ssm = (ssm * dA[:, :, None, None]
                   + jnp.einsum("bhp,bn,bh->bhpn", xh, Bc, dt))
        y = jnp.einsum("bhpn,bn->bhp", new_ssm, Cc)
        y = y + xh * blk["D"][None, :, None]
        y = cm.rmsnorm(y.reshape(B, di).astype(cd) * jax.nn.silu(z),
                       blk["out_norm"])
        out = jnp.einsum("be,ed->bd", y.astype(cd), blk["out_proj"].astype(cd))
        return carry + out, (new_ssm, new_conv)

    x, (ssm_states, conv_states) = jax.lax.scan(
        body, x, (params["blocks"], cache["ssm"], cache["conv"]))
    h_last = cm.rmsnorm(x, params["final_norm"]).astype(jnp.float32)
    logits = jnp.einsum("bd,vd->bv", h_last,
                        params["embed"].astype(jnp.float32))
    return logits, {"ssm": ssm_states, "conv": conv_states,
                    "pos": cache["pos"] + 1}


@register_family("ssm")
def build_ssm(cfg: ModelConfig, quant: QuantConfig) -> Model:
    from repro.models.transformer import _dense_batch_spec, _dense_batch_axes
    return Model(
        config=cfg, quant=quant,
        init=functools.partial(init_params, cfg=cfg),
        param_axes=lambda: param_axes(cfg),
        loss_fn=functools.partial(lm_loss, cfg=cfg, quant=quant),
        batch_spec=_dense_batch_spec(cfg),
        batch_axes=_dense_batch_axes(cfg),
        prefill=functools.partial(prefill, cfg=cfg, quant=quant),
        decode_step=functools.partial(decode_step, cfg=cfg, quant=quant),
        cache_spec=functools.partial(cache_spec, cfg),
        cache_axes=lambda: cache_axes(cfg),
    )
