"""Mixture-of-Experts LM (kimi-k2 / arctic families).

Transformer blocks with GQA attention (shared with repro.models.transformer)
and a top-k routed expert MLP.  Two dispatch implementations:

* ``dense``   — every expert processes every token, outputs combined with the
  (sparse) gate weights.  Exact reference; O(E) FLOPs — smoke tests only.
* ``capacity`` — Switch-style capacity dispatch built from *scatter/gather*
  (never one-hot einsums, whose dispatch FLOPs would dominate): per example,
  position-in-expert comes from a cumulative sum over the (S, E) assignment
  counts; tokens beyond capacity overflow into a sacrificial slot that is
  sliced away.  Expert GEMMs are (E, C, d) x (E, d, f) batched matmuls so
  HLO FLOPs equal the *active* compute (6·N_active·D roofline accounting),
  and the expert dim shards over the ``model`` mesh axis (EP).

Arctic additionally has a dense residual MLP alongside the MoE FFN.
DPQuant applicability: expert GEMMs + attention GEMMs quantize under the
block's policy flag; the router stays fp32 (tiny + numerically sensitive).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, QuantConfig
from repro.models import common as cm
from repro.models import transformer as tfm
from repro.models.registry import Model, register_family
from repro.parallel.axes import logical_constraint as lc


# --------------------------------------------------------------------------- #
# params
# --------------------------------------------------------------------------- #
def init_moe_blocks(key, cfg: ModelConfig):
    L, d, E, f = cfg.n_layers, cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    pdt = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 6)
    blocks = tfm.init_block_stack(keys[0], cfg, L)
    # replace the dense MLP with router + experts (keep attn params)
    for k in ("wi_gate", "wi_up", "wo_mlp"):
        del blocks[k]
    blocks["router"] = cm.dense_init(keys[1], (L, d, E), d, jnp.float32)
    blocks["e_gate"] = cm.dense_init(keys[2], (L, E, d, f), d, pdt)
    blocks["e_up"] = cm.dense_init(keys[3], (L, E, d, f), d, pdt)
    blocks["e_down"] = cm.dense_init(keys[4], (L, E, f, d), f, pdt)
    if cfg.dense_ff_residual:
        fr = cfg.dense_ff_residual
        blocks["r_gate"] = cm.dense_init(keys[5], (L, d, fr), d, pdt)
        blocks["r_up"] = cm.dense_init(jax.random.fold_in(keys[5], 1),
                                       (L, d, fr), d, pdt)
        blocks["r_down"] = cm.dense_init(jax.random.fold_in(keys[5], 2),
                                         (L, fr, d), fr, pdt)
    return blocks


def moe_block_axes(cfg: ModelConfig):
    axes = dict(tfm.BLOCK_AXES)
    for k in ("wi_gate", "wi_up", "wo_mlp"):
        del axes[k]
    axes["router"] = ("layers", "embed", None)
    axes["e_gate"] = ("layers", "experts", "embed", "expert_mlp")
    axes["e_up"] = ("layers", "experts", "embed", "expert_mlp")
    axes["e_down"] = ("layers", "experts", "expert_mlp", "embed")
    if cfg.dense_ff_residual:
        axes["r_gate"] = ("layers", "embed", "mlp")
        axes["r_up"] = ("layers", "embed", "mlp")
        axes["r_down"] = ("layers", "mlp", "embed")
    return axes


def init_params(key, cfg: ModelConfig):
    pdt = jnp.dtype(cfg.param_dtype)
    k_embed, k_blocks = jax.random.split(key)
    return {
        "embed": cm.embed_init(k_embed, (cfg.padded_vocab, cfg.d_model), pdt),
        "final_norm": jnp.zeros((cfg.d_model,), pdt),
        "blocks": init_moe_blocks(k_blocks, cfg),
    }


def param_axes(cfg: ModelConfig):
    return {
        "embed": ("vocab", "embed"),
        "final_norm": ("embed",),
        "blocks": moe_block_axes(cfg),
    }


# --------------------------------------------------------------------------- #
# dispatch
# --------------------------------------------------------------------------- #
def _route(h, router_w, cfg: ModelConfig):
    """Router probs + top-k. h: (T, d) -> ids (T, k), probs (T, k)."""
    logits = jnp.einsum("td,de->te", h.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_ids = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_ids, top_p.astype(jnp.float32)


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    factor = cfg.moe_capacity_factor
    return max(1, min(n_tokens,
                      int(math.ceil(n_tokens * cfg.top_k * factor
                                    / cfg.n_experts))))


def moe_ffn_capacity(h, blk, flag, seed, cfg: ModelConfig, quant: QuantConfig):
    """Capacity-based scatter/gather MoE for one example: h (S, d)."""
    S, d = h.shape
    E, f, k = cfg.n_experts, cfg.expert_d_ff, cfg.top_k
    C = _capacity(cfg, S)
    ids, gates = _route(h, blk["router"], cfg)              # (S, k)

    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.int32)        # (S, k, E)
    counts = onehot.reshape(S * k, E)
    pos_flat = jnp.cumsum(counts, axis=0) - counts          # (S*k, E)
    pos = jnp.take_along_axis(
        pos_flat.reshape(S, k, E), ids[..., None], axis=-1)[..., 0]  # (S, k)
    overflow = pos >= C
    pos_c = jnp.where(overflow, C, pos)                     # overflow slot C

    # dispatch: (E, C+1, d) buffers; slot C collects overflow and is dropped
    buf = jnp.zeros((E, C + 1, d), h.dtype)
    flat_ids = ids.reshape(-1)
    flat_pos = pos_c.reshape(-1)
    xk = jnp.broadcast_to(h[:, None, :], (S, k, d)).reshape(S * k, d)
    buf = buf.at[flat_ids, flat_pos].add(xk)
    xe = buf[:, :C, :]                                      # (E, C, d)
    xe = lc(xe, "experts", None, "embed")

    # expert GEMMs (quantized under the block flag)
    qp = functools.partial(cm.qproj, quant_cfg=quant, flag=flag)
    cd = h.dtype
    g = qp("ecd,edf->ecf", xe, blk["e_gate"].astype(cd), seed=seed + 10)
    u = qp("ecd,edf->ecf", xe, blk["e_up"].astype(cd), seed=seed + 11)
    a = jax.nn.silu(g) * u
    a = lc(a, "experts", None, "expert_mlp")
    ye = qp("ecf,efd->ecd", a, blk["e_down"].astype(cd), seed=seed + 12)

    # combine: gather back, weight by gates, drop overflow
    ye_pad = jnp.concatenate([ye, jnp.zeros((E, 1, d), ye.dtype)], axis=1)
    yk = ye_pad[flat_ids, flat_pos].reshape(S, k, d)
    w = jnp.where(overflow, 0.0, gates).astype(ye.dtype)
    return jnp.einsum("skd,sk->sd", yk, w)


def moe_ffn_dense(h, blk, flag, seed, cfg: ModelConfig, quant: QuantConfig):
    """Reference: all experts compute all tokens. h: (S, d)."""
    ids, gates = _route(h, blk["router"], cfg)              # (S, k)
    qp = functools.partial(cm.qproj, quant_cfg=quant, flag=flag)
    cd = h.dtype
    g = qp("sd,edf->esf", h, blk["e_gate"].astype(cd), seed=seed + 10)
    u = qp("sd,edf->esf", h, blk["e_up"].astype(cd), seed=seed + 11)
    a = jax.nn.silu(g) * u
    y = qp("esf,efd->esd", a, blk["e_down"].astype(cd), seed=seed + 12)
    # sparse combine
    E = cfg.n_experts
    comb = jnp.zeros((h.shape[0], E), jnp.float32)
    comb = comb.at[jnp.arange(h.shape[0])[:, None], ids].add(gates)
    return jnp.einsum("esd,se->sd", y, comb.astype(y.dtype))


def moe_block(x, blk, flag, lidx, positions, cfg: ModelConfig,
              quant: QuantConfig):
    seed = lidx.astype(jnp.uint32) * jnp.uint32(97)
    attn_out, _ = tfm.attention_block(x, blk, flag, seed, positions, cfg, quant)
    x = lc(x + attn_out, "batch", "seq", "embed")
    h = cm.rmsnorm(x, blk["mlp_norm"]).astype(x.dtype)
    ffn = moe_ffn_capacity if cfg.moe_impl == "capacity" else moe_ffn_dense
    y = jax.vmap(lambda hh: ffn(hh, blk, flag, seed, cfg, quant))(h)
    if cfg.dense_ff_residual:
        qp = functools.partial(cm.qproj, quant_cfg=quant, flag=flag)
        cd = x.dtype
        g = qp("bsd,df->bsf", h, blk["r_gate"].astype(cd), seed=seed + 20)
        u = qp("bsd,df->bsf", h, blk["r_up"].astype(cd), seed=seed + 21)
        y = y + qp("bsf,fd->bsd", jax.nn.silu(g) * u,
                   blk["r_down"].astype(cd), seed=seed + 22)
    return lc(x + y, "batch", "seq", "embed")


def lm_loss(params, batch, rng, qflags, cfg: ModelConfig, quant: QuantConfig):
    del rng
    tokens = batch["tokens"]
    cd = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    x = lc(x, "batch", "seq", "embed")
    positions = jnp.arange(tokens.shape[1])[None, :]
    x = tfm.run_block_stack(x, params["blocks"], qflags, positions, cfg,
                            quant, block_fn=moe_block)
    h = cm.rmsnorm(x, params["final_norm"])
    return cm.chunked_lm_loss(h[:, :-1], tokens[:, 1:], params["embed"],
                              real_vocab=cfg.vocab_size, ce_chunk=cfg.ce_chunk)


# --------------------------------------------------------------------------- #
# serving
# --------------------------------------------------------------------------- #
def prefill(params, batch, cfg: ModelConfig, quant: QuantConfig,
            cache_len=None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache_len = cache_len or S
    cd = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    x = lc(x, "batch", "seq", "embed")
    positions = jnp.arange(S)[None, :]
    qflags = jnp.zeros((cfg.n_layers,), jnp.float32)

    def body(carry, xs):
        blk, flag, lidx = xs
        seed = lidx.astype(jnp.uint32) * jnp.uint32(97)
        attn_out, (k, v) = tfm.attention_block(carry, blk, flag, seed,
                                               positions, cfg, quant)
        x2 = lc(carry + attn_out, "batch", "seq", "embed")
        h = cm.rmsnorm(x2, blk["mlp_norm"]).astype(x2.dtype)
        ffn = (moe_ffn_capacity if cfg.moe_impl == "capacity"
               else moe_ffn_dense)
        y = jax.vmap(lambda hh: ffn(hh, blk, flag, seed, cfg, quant))(h)
        if cfg.dense_ff_residual:
            g = jnp.einsum("bsd,df->bsf", h, blk["r_gate"].astype(x2.dtype))
            u = jnp.einsum("bsd,df->bsf", h, blk["r_up"].astype(x2.dtype))
            y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                               blk["r_down"].astype(x2.dtype))
        x2 = lc(x2 + y, "batch", "seq", "embed")
        kc = jnp.transpose(k, (0, 2, 1, 3))
        vc = jnp.transpose(v, (0, 2, 1, 3))
        if cache_len > S:
            pad = [(0, 0), (0, 0), (0, cache_len - S), (0, 0)]
            kc, vc = jnp.pad(kc, pad), jnp.pad(vc, pad)
        return x2, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["blocks"], qflags, jnp.arange(cfg.n_layers)))
    h_last = cm.rmsnorm(x[:, -1], params["final_norm"]).astype(jnp.float32)
    logits = jnp.einsum("bd,vd->bv", h_last,
                        params["embed"].astype(jnp.float32))
    return logits, {"k": ks, "v": vs, "pos": jnp.asarray(S, jnp.int32)}


def decode_step(params, cache, token, cfg: ModelConfig, quant: QuantConfig):
    cd = jnp.dtype(cfg.compute_dtype)
    B = token.shape[0]
    pos = cache["pos"]
    x = jnp.take(params["embed"], token, axis=0).astype(cd)
    positions = jnp.full((B, 1), pos, jnp.int32)
    zero_flag = jnp.float32(0.0)

    def body(carry, xs):
        blk, kc, vc, lidx = xs
        h = cm.rmsnorm(carry, blk["attn_norm"]).astype(cd)
        q = jnp.einsum("bd,dhk->bhk", h, blk["wq"].astype(cd))
        k = jnp.einsum("bd,dhk->bhk", h, blk["wk"].astype(cd))
        v = jnp.einsum("bd,dhk->bhk", h, blk["wv"].astype(cd))
        q = cm.rope(q[:, None], positions, cfg.rope_theta)[:, 0]
        k = cm.rope(k[:, None], positions, cfg.rope_theta)[:, 0]
        kc = jax.lax.dynamic_update_slice(
            kc, k[:, :, None, :].astype(kc.dtype), (0, 0, pos, 0))
        vc = jax.lax.dynamic_update_slice(
            vc, v[:, :, None, :].astype(vc.dtype), (0, 0, pos, 0))
        ctx = tfm.decode_attend(q, kc, vc, pos, cfg)
        x2 = carry + jnp.einsum("bhk,hkd->bd", ctx.astype(cd),
                                blk["wo"].astype(cd))
        h2 = cm.rmsnorm(x2, blk["mlp_norm"]).astype(cd)
        ffn = (moe_ffn_capacity if cfg.moe_impl == "capacity"
               else moe_ffn_dense)
        seed = lidx.astype(jnp.uint32) * jnp.uint32(97)
        y = jax.vmap(lambda hh: ffn(hh[None], blk, zero_flag, seed, cfg,
                                    quant)[0])(h2)
        if cfg.dense_ff_residual:
            g = jnp.einsum("bd,df->bf", h2, blk["r_gate"].astype(cd))
            u = jnp.einsum("bd,df->bf", h2, blk["r_up"].astype(cd))
            y = y + jnp.einsum("bf,fd->bd", jax.nn.silu(g) * u,
                               blk["r_down"].astype(cd))
        return x2 + y, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"],
                  jnp.arange(cfg.n_layers)))
    h_last = cm.rmsnorm(x, params["final_norm"]).astype(jnp.float32)
    logits = jnp.einsum("bd,vd->bv", h_last,
                        params["embed"].astype(jnp.float32))
    return logits, {"k": ks, "v": vs, "pos": pos + 1}


@register_family("moe_lm")
def build_moe_lm(cfg: ModelConfig, quant: QuantConfig) -> Model:
    return Model(
        config=cfg, quant=quant,
        init=functools.partial(init_params, cfg=cfg),
        param_axes=lambda: param_axes(cfg),
        loss_fn=functools.partial(lm_loss, cfg=cfg, quant=quant),
        batch_spec=tfm._dense_batch_spec(cfg),
        batch_axes=tfm._dense_batch_axes(cfg),
        prefill=functools.partial(prefill, cfg=cfg, quant=quant),
        decode_step=functools.partial(decode_step, cfg=cfg, quant=quant),
        cache_spec=functools.partial(tfm.kv_cache_spec, cfg),
        cache_axes=lambda: tfm.kv_cache_axes(cfg),
    )
