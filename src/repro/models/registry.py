"""Model abstraction + registry.

A ``Model`` bundles pure functions; params/caches are plain pytrees. Logical
axis pytrees mirror the param/cache structure and feed the partitioner.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

from repro.config import ModelConfig, QuantConfig


@dataclasses.dataclass
class Model:
    config: ModelConfig
    quant: QuantConfig
    # training
    init: Callable                    # key -> params
    param_axes: Callable              # () -> axes pytree (matches params)
    loss_fn: Callable                 # (params, batch, rng, qflags) -> scalar
    batch_spec: Callable              # (batch, seq) -> {name: ShapeDtypeStruct}
    batch_axes: Callable              # () -> {name: logical axes tuple}
    # serving (decoder families only)
    prefill: Optional[Callable] = None       # (params, batch) -> (logits, cache)
    decode_step: Optional[Callable] = None   # (params, cache, token) -> (logits, cache)
    cache_spec: Optional[Callable] = None    # (batch, seq) -> cache ShapeDtypeStructs
    cache_axes: Optional[Callable] = None
    # continuous-batching serving (slot-pool cache; see repro.serve.engine):
    # decode_slots(params, cache, tokens, active) -> (logits, cache) where
    # cache["pos"] is a per-slot (K,) position vector and ``active`` masks
    # which slots advance this tick.
    decode_slots: Optional[Callable] = None
    slot_cache_spec: Optional[Callable] = None  # (n_slots, max_seq) -> specs
    # KV-cache storage formats this family's serve path supports
    # (repro.config.KV_CACHE_FORMATS subset).  Families that accept a
    # ``kv_fmt`` kwarg on prefill/decode_step/decode_slots/cache_spec/
    # cache_axes/slot_cache_spec list the quantized formats here; callers
    # only pass the kwarg for formats beyond "none", so ("none",)-only
    # families keep their original signatures.
    kv_formats: tuple = ("none",)
    # ghost-clipping support (repro.dp.ghost; DPConfig.grad_mode="ghost"):
    # per_example_loss(params, batch, rng, qflags) -> (B,) batched losses
    # (row i == loss_fn on example i alone); ghost_mask(params) -> bool
    # pytree marking the leaves whose per-example grad norms are covered
    # by the qeinsum/qconv2d ghost hooks (False leaves use the vmapped
    # norm-only fallback).
    per_example_loss: Optional[Callable] = None
    ghost_mask: Optional[Callable] = None
    # ghost_aux(qflags) -> repro.dp.ghost.GhostAux: the model's extra
    # pass-1 hooks (embedding gather Gram, single-chunk LM head, norm
    # scales) — with them the family runs ghost pass 1 with ZERO
    # vmapped-fallback parameters.  None = op-level hooks + fallback only.
    ghost_aux: Optional[Callable] = None

    @property
    def n_policy_layers(self) -> int:
        return self.config.policy_len()


_BUILDERS: Dict[str, Callable[[ModelConfig, QuantConfig], Model]] = {}


def register_family(name: str):
    def deco(fn):
        _BUILDERS[name] = fn
        return fn
    return deco


def build_model(config: ModelConfig, quant: Optional[QuantConfig] = None) -> Model:
    quant = quant or QuantConfig()
    # import model modules lazily so registration happens on demand
    import importlib
    for mod in ("transformer", "moe", "mamba2", "griffin", "encdec", "vlm",
                "resnet", "densenet", "bert"):
        try:
            importlib.import_module(f"repro.models.{mod}")
        except ModuleNotFoundError:  # pragma: no cover - during bring-up
            pass
    if config.family not in _BUILDERS:
        raise ValueError(f"unknown model family: {config.family}")
    return _BUILDERS[config.family](config, quant)
