"""ResNet-18/50 (the paper's primary CNNs), DP-compatible (GroupNorm).

CIFAR/GTSRB-style stem (3x3, stride 1) for 32x32 synthetic inputs.
BatchNorm is replaced with GroupNorm — per-example DP gradients forbid
cross-example statistics (Opacus imposes the same conversion).

DPQuant policy granularity: the stem + every residual block is one
schedulable "layer" (matches the paper's per-layer conv quantization);
``qconv2d`` gates every conv GEMM (fwd/dgrad/wgrad) under the block's flag.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, QuantConfig
from repro.models import common as cm
from repro.models.registry import Model, register_family
from repro.quant.fake_quant import qconv2d


def _conv_init(key, shape):  # HWIO
    fan_in = shape[0] * shape[1] * shape[2]
    return jax.random.normal(key, shape, jnp.float32) * math.sqrt(2.0 / fan_in)


def _gn_params(c):
    return {"scale": jnp.ones((c,), jnp.float32),
            "bias": jnp.zeros((c,), jnp.float32)}


def _is_bottleneck(cfg: ModelConfig) -> bool:
    return sum(cfg.resnet_blocks) > 8          # resnet50 (3,4,6,3)


def init_params(key, cfg: ModelConfig):
    blocks_per_stage = cfg.resnet_blocks
    bottleneck = _is_bottleneck(cfg)
    widths = [64, 128, 256, 512]
    expansion = 4 if bottleneck else 1
    params = {"stem": {"conv": _conv_init(key, (3, 3, cfg.in_channels, 64)),
                       "gn": _gn_params(64)}}
    keys = jax.random.split(key, 64)
    ki = 1
    in_c = 64
    stages = []
    for si, (n, w) in enumerate(zip(blocks_per_stage, widths)):
        stage = []
        for bi in range(n):
            stride = 2 if (si > 0 and bi == 0) else 1
            out_c = w * expansion
            blk = {}
            if bottleneck:
                blk["conv1"] = _conv_init(keys[ki], (1, 1, in_c, w)); ki += 1
                blk["gn1"] = _gn_params(w)
                blk["conv2"] = _conv_init(keys[ki], (3, 3, w, w)); ki += 1
                blk["gn2"] = _gn_params(w)
                blk["conv3"] = _conv_init(keys[ki], (1, 1, w, out_c)); ki += 1
                blk["gn3"] = _gn_params(out_c)
            else:
                blk["conv1"] = _conv_init(keys[ki], (3, 3, in_c, w)); ki += 1
                blk["gn1"] = _gn_params(w)
                blk["conv2"] = _conv_init(keys[ki], (3, 3, w, out_c)); ki += 1
                blk["gn2"] = _gn_params(out_c)
            if stride != 1 or in_c != out_c:
                blk["proj"] = _conv_init(keys[ki], (1, 1, in_c, out_c)); ki += 1
                blk["proj_gn"] = _gn_params(out_c)
            blk["stride"] = stride  # static int, stored as aux (removed below)
            stage.append(blk)
            in_c = out_c
            if ki >= 60:
                keys = jax.random.split(keys[-1], 64)
                ki = 0
        stages.append(stage)
    # strides are static structure; strip them from the param pytree
    strides = [[b.pop("stride") for b in st] for st in stages]
    params["stages"] = stages
    params["head"] = {
        "w": jax.random.normal(keys[ki], (in_c, cfg.num_classes),
                               jnp.float32) / math.sqrt(in_c),
        "b": jnp.zeros((cfg.num_classes,), jnp.float32)}
    return params


def _static_strides(cfg: ModelConfig):
    return [[(2 if (si > 0 and bi == 0) else 1) for bi in range(n)]
            for si, n in enumerate(cfg.resnet_blocks)]


def param_axes(cfg: ModelConfig):
    def conv_ax():
        return (None, None, None, "mlp")
    bottleneck = _is_bottleneck(cfg)

    def blk_axes(has_proj):
        ax = {"conv1": conv_ax(), "gn1": {"scale": (None,), "bias": (None,)},
              "conv2": conv_ax(), "gn2": {"scale": (None,), "bias": (None,)}}
        if bottleneck:
            ax["conv3"] = conv_ax()
            ax["gn3"] = {"scale": (None,), "bias": (None,)}
        if has_proj:
            ax["proj"] = conv_ax()
            ax["proj_gn"] = {"scale": (None,), "bias": (None,)}
        return ax

    widths = [64, 128, 256, 512]
    expansion = 4 if bottleneck else 1
    stages = []
    in_c = 64
    for si, (n, w) in enumerate(zip(cfg.resnet_blocks, widths)):
        st = []
        for bi in range(n):
            stride = 2 if (si > 0 and bi == 0) else 1
            out_c = w * expansion
            st.append(blk_axes(stride != 1 or in_c != out_c))
            in_c = out_c
        stages.append(st)
    return {"stem": {"conv": conv_ax(),
                     "gn": {"scale": (None,), "bias": (None,)}},
            "stages": stages,
            "head": {"w": (None, None), "b": (None,)}}


def forward(params, image, qflags, cfg: ModelConfig, quant: QuantConfig):
    bottleneck = _is_bottleneck(cfg)
    strides = _static_strides(cfg)
    li = 0  # policy layer index

    def qc(x, w, flag, seed, stride=1):
        return qconv2d(x, w, seed=jnp.uint32(seed), flag=flag,
                       strides=(stride, stride), padding="SAME",
                       fmt=quant.fmt, q_fwd=quant.quantize_fwd,
                       q_dgrad=quant.quantize_dgrad,
                       q_wgrad=quant.quantize_wgrad,
                       backend=quant.backend)

    x = qc(image, params["stem"]["conv"], qflags[li], 11 * li)
    x = cm.groupnorm(x, params["stem"]["gn"]["scale"],
                     params["stem"]["gn"]["bias"])
    x = jax.nn.relu(x)
    li += 1
    for si, stage in enumerate(params["stages"]):
        for bi, blk in enumerate(stage):
            stride = strides[si][bi]
            flag = qflags[li]
            sd = 11 * li
            shortcut = x
            if bottleneck:
                h = jax.nn.relu(cm.groupnorm(
                    qc(x, blk["conv1"], flag, sd),
                    blk["gn1"]["scale"], blk["gn1"]["bias"]))
                h = jax.nn.relu(cm.groupnorm(
                    qc(h, blk["conv2"], flag, sd + 1, stride),
                    blk["gn2"]["scale"], blk["gn2"]["bias"]))
                h = cm.groupnorm(qc(h, blk["conv3"], flag, sd + 2),
                                 blk["gn3"]["scale"], blk["gn3"]["bias"])
            else:
                h = jax.nn.relu(cm.groupnorm(
                    qc(x, blk["conv1"], flag, sd, stride),
                    blk["gn1"]["scale"], blk["gn1"]["bias"]))
                h = cm.groupnorm(qc(h, blk["conv2"], flag, sd + 1),
                                 blk["gn2"]["scale"], blk["gn2"]["bias"])
            if "proj" in blk:
                shortcut = cm.groupnorm(
                    qc(x, blk["proj"], flag, sd + 3, stride),
                    blk["proj_gn"]["scale"], blk["proj_gn"]["bias"])
            x = jax.nn.relu(h + shortcut)
            li += 1
    x = x.mean(axis=(1, 2))
    return x @ params["head"]["w"] + params["head"]["b"]


def loss_fn(params, batch, rng, qflags, cfg: ModelConfig, quant: QuantConfig,
            per_example: bool = False):
    del rng
    logits = forward(params, batch["image"], qflags, cfg, quant)
    return cm.softmax_xent(logits, batch["label"], per_example=per_example)


def conv_ghost_mask(params):
    """Ghost hooks cover every qconv2d kernel (stem/blocks/projections);
    GroupNorm scales/biases and the dense head use the vmapped fallback.
    Shared by the resnet and densenet families (leaf-name convention:
    conv kernels live under a ``conv*``/``proj`` dict key)."""
    def mark(path, _):
        keys = [p.key for p in path
                if isinstance(p, jax.tree_util.DictKey)]
        return bool(keys) and (keys[-1].startswith("conv")
                               or keys[-1] == "proj")
    return jax.tree_util.tree_map_with_path(mark, params)


@register_family("resnet")
def build_resnet(cfg: ModelConfig, quant: QuantConfig) -> Model:
    def batch_spec(batch: int, seq: int = 0):
        s = cfg.image_size
        return {"image": jax.ShapeDtypeStruct((batch, s, s, cfg.in_channels),
                                              jnp.float32),
                "label": jax.ShapeDtypeStruct((batch,), jnp.int32)}

    def batch_axes():
        return {"image": ("batch", None, None, None), "label": ("batch",)}

    return Model(
        config=cfg, quant=quant,
        init=functools.partial(init_params, cfg=cfg),
        param_axes=lambda: param_axes(cfg),
        loss_fn=functools.partial(loss_fn, cfg=cfg, quant=quant),
        batch_spec=batch_spec,
        batch_axes=batch_axes,
        per_example_loss=functools.partial(loss_fn, cfg=cfg, quant=quant,
                                           per_example=True),
        ghost_mask=conv_ghost_mask,
    )
