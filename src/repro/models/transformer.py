"""Dense decoder-only GQA transformer (gemma / yi / stablelm families).

Structure: RMSNorm -> GQA attention (RoPE) -> residual -> RMSNorm -> gated
MLP (GeGLU/SwiGLU) -> residual; tied embeddings by default; layers executed
with ``lax.scan``; DPQuant per-layer flags gate every GEMM through
``repro.quant.fake_quant.qeinsum`` (forward + dgrad + wgrad quantization).

Sharding-driven padding (DESIGN.md §5): query heads are padded up to
``pad_heads_to`` (extra heads zero-initialized); the vocab is padded to
``pad_vocab_to`` (padded logits masked in the loss).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, QuantConfig
from repro.models import common as cm
from repro.models.registry import Model, register_family
from repro.parallel.axes import logical_constraint as lc


# --------------------------------------------------------------------------- #
# params
# --------------------------------------------------------------------------- #
def init_block_stack(key, cfg: ModelConfig, n_layers: int):
    d, hp, kv, hd, f = (cfg.d_model, cfg.padded_heads, cfg.n_kv_heads,
                        cfg.head_dim, cfg.d_ff)
    pdt = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    L = n_layers

    def dinit(k, shape, fan_in):
        return cm.dense_init(k, shape, fan_in, pdt)

    wq = dinit(keys[0], (L, d, hp, hd), d)
    if hp != cfg.n_heads:
        # zero the padded query heads so padding is semantics-preserving
        head_mask = (jnp.arange(hp) < cfg.n_heads).astype(pdt)
        wq = wq * head_mask[None, None, :, None]
    blocks = {
        "attn_norm": jnp.zeros((L, d), pdt),
        "wq": wq,
        "wk": dinit(keys[1], (L, d, kv, hd), d),
        "wv": dinit(keys[2], (L, d, kv, hd), d),
        "wo": dinit(keys[3], (L, hp, hd, d), hp * hd),
        "mlp_norm": jnp.zeros((L, d), pdt),
        "wi_gate": dinit(keys[4], (L, d, f), d),
        "wi_up": dinit(keys[5], (L, d, f), d),
        "wo_mlp": dinit(keys[6], (L, f, d), f),
    }
    return blocks


BLOCK_AXES = {
    "attn_norm": ("layers", "embed"),
    "wq": ("layers", "embed", "heads", "head_dim"),
    "wk": ("layers", "embed", "kv_heads", "head_dim"),
    "wv": ("layers", "embed", "kv_heads", "head_dim"),
    "wo": ("layers", "heads", "head_dim", "embed"),
    "mlp_norm": ("layers", "embed"),
    "wi_gate": ("layers", "embed", "mlp"),
    "wi_up": ("layers", "embed", "mlp"),
    "wo_mlp": ("layers", "mlp", "embed"),
}


def init_params(key, cfg: ModelConfig):
    pdt = jnp.dtype(cfg.param_dtype)
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    params = {
        "embed": cm.embed_init(k_embed, (cfg.padded_vocab, cfg.d_model), pdt),
        "final_norm": jnp.zeros((cfg.d_model,), pdt),
        "blocks": init_block_stack(k_blocks, cfg, cfg.n_layers),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = cm.dense_init(
            k_head, (cfg.d_model, cfg.padded_vocab), cfg.d_model, pdt)
    return params


def param_axes(cfg: ModelConfig):
    axes = {
        "embed": ("vocab", "embed"),
        "final_norm": ("embed",),
        "blocks": dict(BLOCK_AXES),
    }
    if not cfg.tie_embeddings:
        axes["lm_head"] = ("embed", "vocab")
    return axes


# --------------------------------------------------------------------------- #
# forward
# --------------------------------------------------------------------------- #
def _activation(gate, up, kind: str):
    if kind == "geglu":
        return jax.nn.gelu(gate) * up
    if kind == "swiglu":
        return jax.nn.silu(gate) * up
    if kind == "gelu":
        return jax.nn.gelu(gate)
    if kind == "relu":
        return jax.nn.relu(gate)
    raise ValueError(kind)


def attention_block(x, blk, flag, seed, positions, cfg: ModelConfig,
                    quant: QuantConfig):
    """Pre-norm GQA attention with RoPE; returns the residual branch."""
    qp = functools.partial(cm.qproj, quant_cfg=quant, flag=flag)
    h = cm.rmsnorm(x, blk["attn_norm"])
    cd = jnp.dtype(cfg.compute_dtype)
    h = h.astype(cd)
    q = qp("bsd,dhk->bshk", h, blk["wq"].astype(cd), seed=seed)
    k = qp("bsd,dhk->bshk", h, blk["wk"].astype(cd), seed=seed + 1)
    v = qp("bsd,dhk->bshk", h, blk["wv"].astype(cd), seed=seed + 2)
    q = lc(q, "batch", "seq", "heads", "head_dim")
    q = cm.rope(q, positions, cfg.rope_theta)
    k = cm.rope(k, positions, cfg.rope_theta)
    n_rep = cfg.padded_heads // cfg.n_kv_heads
    kr, vr = cm.repeat_kv(k, n_rep), cm.repeat_kv(v, n_rep)
    out = cm.chunked_causal_attention(
        q, kr, vr, chunk_q=cfg.attn_chunk_q, causal=True,
        scale=1.0 / math.sqrt(cfg.head_dim))
    out = lc(out, "batch", "seq", "heads", "head_dim")
    res = qp("bshk,hkd->bsd", out, blk["wo"].astype(cd), seed=seed + 3)
    return res, (k, v)  # compact (pre-repeat) KV for cache reuse


def mlp_block(x, blk, flag, seed, cfg: ModelConfig, quant: QuantConfig):
    qp = functools.partial(cm.qproj, quant_cfg=quant, flag=flag)
    cd = jnp.dtype(cfg.compute_dtype)
    h = cm.rmsnorm(x, blk["mlp_norm"]).astype(cd)
    gate = qp("bsd,df->bsf", h, blk["wi_gate"].astype(cd), seed=seed + 4)
    up = qp("bsd,df->bsf", h, blk["wi_up"].astype(cd), seed=seed + 5)
    act = _activation(gate, up, cfg.mlp_activation)
    act = lc(act, "batch", "seq", "mlp")
    return qp("bsf,fd->bsd", act, blk["wo_mlp"].astype(cd), seed=seed + 6)


def transformer_block(x, blk, flag, lidx, positions, cfg, quant):
    seed = lidx.astype(jnp.uint32) * jnp.uint32(97)
    attn_out, _ = attention_block(x, blk, flag, seed, positions, cfg, quant)
    x = lc(x + attn_out, "batch", "seq", "embed")
    x = lc(x + mlp_block(x, blk, flag, seed, cfg, quant),
           "batch", "seq", "embed")
    return x


def run_block_stack(x, blocks, qflags, positions, cfg: ModelConfig,
                    quant: QuantConfig, block_fn=transformer_block):
    L = jax.tree_util.tree_leaves(blocks)[0].shape[0]

    def apply_block(carry, blk, flag, lidx):
        return block_fn(carry, blk, flag, lidx, positions, cfg, quant)

    if cfg.remat:
        apply_block = jax.checkpoint(apply_block)

    def body(carry, xs):
        blk, flag, lidx = xs
        return apply_block(carry, blk, flag, lidx), None

    x, _ = jax.lax.scan(body, x, (blocks, qflags, jnp.arange(L)))
    return x


def forward_hidden(params, tokens, qflags, cfg: ModelConfig,
                   quant: QuantConfig, inputs_embeds: Optional[jax.Array] = None,
                   embed_tap: Optional[jax.Array] = None):
    cd = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    if cfg.family == "dense_lm":
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cd)  # gemma-style scaling
    if embed_tap is not None:
        # ghost pass-1 gather hook (repro.dp.ghost.GhostAux): the tap's
        # cotangent is the embedding-output cotangent the scatter-grad
        # would consume; injected post-scaling so the embed grad is
        # sqrt(d_model) * scatter(tokens, cotangent)
        x = x + embed_tap
    if inputs_embeds is not None:
        nv = inputs_embeds.shape[1]
        x = jnp.concatenate([inputs_embeds.astype(cd), x[:, nv:]], axis=1)
    x = lc(x, "batch", "seq", "embed")
    positions = jnp.arange(tokens.shape[1])[None, :]
    x = run_block_stack(x, params["blocks"], qflags, positions, cfg, quant)
    return cm.rmsnorm(x, params["final_norm"])


def lm_loss(params, batch, rng, qflags, cfg: ModelConfig, quant: QuantConfig,
            loss_mask_prefix: int = 0, per_example: bool = False,
            ghost_taps=None):
    del rng
    tokens = batch["tokens"]
    taps = ghost_taps or {}
    h = forward_hidden(params, tokens, qflags, cfg, quant,
                       inputs_embeds=batch.get("vision_embeds"),
                       embed_tap=taps.get("embed_out"))
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"].T
    mask = None
    if loss_mask_prefix:
        mask = (jnp.arange(tokens.shape[1] - 1)[None, :]
                >= loss_mask_prefix).astype(jnp.float32) \
            * jnp.ones((tokens.shape[0], 1), jnp.float32)
    out = cm.chunked_lm_loss(h[:, :-1], tokens[:, 1:], head,
                             real_vocab=cfg.vocab_size,
                             ce_chunk=cfg.ce_chunk, mask=mask,
                             per_example=per_example,
                             logits_tap=taps.get("logits"))
    if ghost_taps is not None:
        loss, hc = out
        return loss, {"hidden": hc}
    return out


# Ghost-clipping hooks (repro.dp.ghost): every block projection runs
# through cm.qproj -> qeinsum and therefore carries a ghost norm hook;
# norm scales are tapped by the ghost rmsnorm hook and the embedding /
# LM head by the GhostAux hooks below, so dense_lm pass 1 has NO
# vmapped-fallback leaves (asserted in tests/test_dp_ghost.py).
_GHOST_HOOKED_LEAVES = frozenset(
    ("wq", "wk", "wv", "wo", "wi_gate", "wi_up", "wo_mlp"))


def ghost_mask(params):
    def mark(path, _):
        keys = [p.key for p in path
                if isinstance(p, jax.tree_util.DictKey)]
        return bool(keys) and keys[-1] in _GHOST_HOOKED_LEAVES
    return jax.tree_util.tree_map_with_path(mark, params)


def make_ghost_aux(qflags, cfg: ModelConfig, quant: QuantConfig):
    """Dense-LM :class:`repro.dp.ghost.GhostAux`: gather + LM-head hooks.

    Per example, the embedding leaf's grad is the sum of a gather-scatter
    term and (tied embeddings) a head term landing on the SAME leaf:

        d_gather = s * A^T C      A = onehot(tokens) (T, V), C = gather-out
                                  cotangent (T, d), s = sqrt(d_model)
        d_head   = G^T H          G = logits cotangent (S-1, V_pad),
                                  H = f32 hidden rows (S-1, d)

    so ``||d_gather + d_head||^2`` needs the token-equality-masked Gram of
    the lookup cotangents, the head's mixed ghost norm, and — because
    both are one stacked matrix product ``[A; G]^T [sC; H]`` — the cross
    term ``2 <d_gather, d_head> = 2 sum_{s,t} G[s, tok_t] <sC_t, H_s>``.
    All three are Gram-sized (O(T^2 d + S^2 V)); the (V, d) per-example
    grad is never formed.  Untied heads drop the cross term (different
    leaves) and split the two norms across embed / lm_head.
    """
    from repro.dp.ghost import GhostAux, _matpair_sq_norm

    cd = jnp.dtype(cfg.compute_dtype)
    emb_scale = math.sqrt(cfg.d_model) if cfg.family == "dense_lm" else 1.0

    def make_taps(ex):
        t = ex["tokens"].shape[-1]
        return {
            "embed_out": jnp.zeros((1, t, cfg.d_model), cd),
            "logits": jnp.zeros((1, t - 1, cfg.padded_vocab), jnp.float32),
        }

    def tapped_loss(params, ex, rng, taps):
        b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
        return lm_loss(params, b1, rng, qflags, cfg=cfg, quant=quant,
                       ghost_taps=taps)

    def combine(cots, fwd, ex):
        c = cots["embed_out"][0].astype(jnp.float32) * emb_scale  # (T, d)
        g = cots["logits"][0].astype(jnp.float32)                 # (S-1, Vp)
        h = fwd["hidden"][0].astype(jnp.float32)                  # (S-1, d)
        tok = ex["tokens"]
        eq = (tok[:, None] == tok[None, :]).astype(jnp.float32)
        sq_gather = jnp.vdot(eq, c @ c.T)
        sq_head = _matpair_sq_norm(h, g)
        if not cfg.tie_embeddings:
            return sq_gather + sq_head
        cross = jnp.vdot(jnp.take(g, tok, axis=1), h @ c.T)
        return sq_gather + sq_head + 2.0 * cross

    def covers(params):
        # embed + (untied) lm_head via the taps above; *_norm scale
        # leaves via the ghost rmsnorm hook (hook_norm_scales)
        def mark(path, _):
            keys = [p.key for p in path
                    if isinstance(p, jax.tree_util.DictKey)]
            name = keys[-1] if keys else ""
            return name in ("embed", "lm_head") or name.endswith("norm")
        return jax.tree_util.tree_map_with_path(mark, params)

    return GhostAux(make_taps=make_taps, tapped_loss=tapped_loss,
                    combine=combine, covers=covers, hook_norm_scales=True)


# --------------------------------------------------------------------------- #
# serving: prefill + decode with KV cache
# --------------------------------------------------------------------------- #
def _kv_impls(kv_fmt: str, quant: Optional[QuantConfig]):
    """Resolve the (kv_quant, decode_attn) impls for a cache format.

    Backend selection rides the same knob as the other quant ops
    (``QuantConfig.backend``, overridden by ``REPRO_QUANT_BACKEND``);
    formats a backend lacks fall back to ref explicitly.  Resolution is
    a trace-time (python) lookup: the format is structural (it changes
    the cache pytree), so switching it recompiles by construction, and
    nothing else about the policy is baked in — per-tick values (tokens,
    positions, active mask) stay traced.
    """
    from repro.quant import backend as qbackend

    be = quant.backend if quant is not None else None
    kvq, _ = qbackend.get_kv_quant(kv_fmt, be)
    attn, _ = qbackend.get_decode_attn(kv_fmt, be)
    return kvq, attn


def kv_cache_spec(cfg: ModelConfig, batch: int, seq_len: int,
                  kv_fmt: str = "none"):
    from repro.quant import kv_cache as kvc

    cd = jnp.dtype(cfg.compute_dtype)
    L, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    code_dt, code_dim = kvc.code_spec(kv_fmt, hd)
    spec = {
        "k": jax.ShapeDtypeStruct((L, batch, kv, seq_len, code_dim),
                                  code_dt or cd),
        "v": jax.ShapeDtypeStruct((L, batch, kv, seq_len, code_dim),
                                  code_dt or cd),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if kv_fmt != "none":
        sds = jax.ShapeDtypeStruct((L, batch, kv, seq_len), kvc.SCALE_DTYPE)
        spec["k_scale"] = sds
        spec["v_scale"] = sds
    return spec


def kv_cache_axes(cfg: ModelConfig, kv_fmt: str = "none"):
    axes = {
        "k": ("layers", "batch", "kv_heads", "kv_seq", "head_dim"),
        "v": ("layers", "batch", "kv_heads", "kv_seq", "head_dim"),
        "pos": None,
    }
    if kv_fmt != "none":
        axes["k_scale"] = ("layers", "batch", "kv_heads", "kv_seq")
        axes["v_scale"] = ("layers", "batch", "kv_heads", "kv_seq")
    return axes


def prefill(params, batch, cfg: ModelConfig, quant: QuantConfig,
            cache_len: Optional[int] = None, kv_fmt: str = "none",
            prompt_len=None):
    """Run the full prompt; return (last-token logits, filled KV cache).

    ``prompt_len`` (None or a traced int32 scalar) supports bucketed
    prefill: the token batch may be padded beyond the real prompt, and
    the last-token logits / cache position / logits-head key fold are
    taken at ``prompt_len`` instead of the padded length.  Padding is
    semantics-preserving because attention is causal (rows < prompt_len
    never see the pad) and every cache row at index >= pos is masked by
    ``decode_attend`` until a decode tick overwrites it — the same
    contract that already covers stale KV in reused slots.

    ``kv_fmt`` selects the cache storage format: quantized formats write
    the scanned K/V rows through the dispatched ``kv_quant`` op and the
    cache grows per-(token, head) bf16 scale arrays (docs/SERVING.md).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache_len = cache_len or S
    cd = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    if cfg.family == "dense_lm":
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cd)
    ve = batch.get("vision_embeds")
    if ve is not None:
        x = jnp.concatenate([ve.astype(cd), x[:, ve.shape[1]:]], axis=1)
    x = lc(x, "batch", "seq", "embed")
    positions = jnp.arange(S)[None, :]
    qflags = jnp.zeros((cfg.n_layers,), jnp.float32)  # serving: no fake-quant

    def body(carry, xs):
        blk, flag, lidx = xs
        seed = lidx.astype(jnp.uint32) * jnp.uint32(97)
        attn_out, (k, v) = attention_block(carry, blk, flag, seed, positions,
                                           cfg, quant)
        x2 = lc(carry + attn_out, "batch", "seq", "embed")
        x2 = lc(x2 + mlp_block(x2, blk, flag, seed, cfg, quant),
                "batch", "seq", "embed")
        kc = jnp.transpose(k, (0, 2, 1, 3))  # (B, KV, S, hd)
        vc = jnp.transpose(v, (0, 2, 1, 3))
        if cache_len > S:
            pad = [(0, 0), (0, 0), (0, cache_len - S), (0, 0)]
            kc, vc = jnp.pad(kc, pad), jnp.pad(vc, pad)
        return x2, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["blocks"], qflags, jnp.arange(cfg.n_layers)))
    if prompt_len is None:
        plen = jnp.asarray(S, jnp.int32)
        x_last = x[:, -1]
    else:
        plen = jnp.asarray(prompt_len, jnp.int32)
        x_last = jax.lax.dynamic_slice_in_dim(x, plen - 1, 1, axis=1)[:, 0]
    h_last = cm.rmsnorm(x_last, params["final_norm"]).astype(jnp.float32)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"].T
    # even folds = prefill, odd folds = decode (pos==S after prefill, so a
    # bare fold of the position would reuse the first decode step's key)
    logits = cm.qlogits(h_last, head, quant_cfg=quant,
                        key=jax.random.fold_in(jax.random.PRNGKey(17),
                                               2 * plen))
    ks = lc(ks, "layers", "batch", "kv_heads", "kv_seq", "head_dim")
    vs = lc(vs, "layers", "batch", "kv_heads", "kv_seq", "head_dim")
    cache = {"k": ks, "v": vs, "pos": plen}
    if kv_fmt != "none":
        kvq, _ = _kv_impls(kv_fmt, quant)
        kc, ksc = kvq(ks)
        vc, vsc = kvq(vs)
        cache = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc,
                 "pos": plen}
    return logits, cache


def decode_attend(q, k_cache, v_cache, pos, cfg: ModelConfig):
    """One-token GQA attention against a (B, KV, S, hd) cache.

    ``pos`` is either a scalar (lockstep decode: every row sits at the same
    position) or a (B,) vector of per-slot positions (continuous batching:
    each slot attends to its own prefix only).  Cache entries beyond a row's
    position are masked to exactly zero probability, so a zero-padded cache
    of any length yields bit-identical attention output.

    This is the ``kv_fmt="none"`` case of the dispatched ``decode_attn``
    op; the historical pure-jnp math lives in
    :func:`repro.quant.kv_cache.ref_decode_attn` (bit-for-bit identical)
    and ``_decode_trunk`` routes every format — including ``none`` —
    through the dispatcher.  This thin alias stays for direct callers.
    """
    from repro.quant import kv_cache as kvc
    return kvc.ref_decode_attn("none", q, k_cache, v_cache, None, None, pos,
                               n_kv=cfg.n_kv_heads,
                               scale=1.0 / math.sqrt(cfg.head_dim))


def _decode_trunk(params, cache, token, pos, cfg: ModelConfig,
                  quant: Optional[QuantConfig] = None, kv_fmt: str = "none"):
    """Shared one-token transformer trunk for lockstep and slot decode.

    ``pos`` is a (B,) per-row position vector (lockstep decode broadcasts
    its scalar); each row's KV is written at its own position and attends
    to its own prefix.  Returns the final-norm hidden states (B, d) f32
    and the updated cache arrays (everything but ``pos``) — the
    logits-head key schedule is the one place the two decode modes
    legitimately differ, so it stays with the callers.

    Quantized cache formats write each row through the dispatched
    ``kv_quant`` op (codes + per-(row, head) bf16 scale) and attend
    through the dispatched ``decode_attn`` op, which fuses dequant into
    the QK/PV contractions on the pallas backend.  Write-then-attend
    order is what makes bucketed prefill and slot reuse safe: the row at
    the slot's own position is always fresh before attention reads it,
    and rows beyond ``pos`` are masked.
    """
    cd = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], token, axis=0).astype(cd)
    if cfg.family == "dense_lm":
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cd)
    positions = pos[:, None]                             # (B, 1)
    quantized = kv_fmt != "none"
    kvq, attend = _kv_impls(kv_fmt, quant)
    attn_scale = 1.0 / math.sqrt(cfg.head_dim)

    # per-row cache write: (KV, S, Dc) gets a (KV, 1, Dc) slab at pos_i;
    # scale rows (KV, S) get a (KV, 1) slab
    write = jax.vmap(
        lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (0, p, 0)))
    swrite = jax.vmap(
        lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (0, p)))

    def body(carry, xs):
        if quantized:
            blk, kc, vc, ksc, vsc = xs
        else:
            blk, kc, vc = xs
            ksc = vsc = None
        h = cm.rmsnorm(carry, blk["attn_norm"]).astype(cd)
        q = jnp.einsum("bd,dhk->bhk", h, blk["wq"].astype(cd))
        k = jnp.einsum("bd,dhk->bhk", h, blk["wk"].astype(cd))
        v = jnp.einsum("bd,dhk->bhk", h, blk["wv"].astype(cd))
        q = cm.rope(q[:, None], positions, cfg.rope_theta)[:, 0]
        k = cm.rope(k[:, None], positions, cfg.rope_theta)[:, 0]
        if quantized:
            k_codes, k_sc = kvq(k)                       # (B, KV, Dc) codes
            v_codes, v_sc = kvq(v)
            kc = write(kc, k_codes[:, :, None, :].astype(kc.dtype), pos)
            vc = write(vc, v_codes[:, :, None, :].astype(vc.dtype), pos)
            ksc = swrite(ksc, k_sc[:, :, None].astype(ksc.dtype), pos)
            vsc = swrite(vsc, v_sc[:, :, None].astype(vsc.dtype), pos)
        else:
            kc = write(kc, k[:, :, None, :].astype(kc.dtype), pos)
            vc = write(vc, v[:, :, None, :].astype(vc.dtype), pos)
        ctx = attend(q, kc, vc, ksc, vsc, pos,
                     n_kv=cfg.n_kv_heads, scale=attn_scale)
        attn_out = jnp.einsum("bhk,hkd->bd", ctx.astype(cd),
                              blk["wo"].astype(cd))
        x2 = carry + attn_out
        h2 = cm.rmsnorm(x2, blk["mlp_norm"]).astype(cd)
        gate = jnp.einsum("bd,df->bf", h2, blk["wi_gate"].astype(cd))
        up = jnp.einsum("bd,df->bf", h2, blk["wi_up"].astype(cd))
        act = _activation(gate, up, cfg.mlp_activation)
        x2 = x2 + jnp.einsum("bf,fd->bd", act, blk["wo_mlp"].astype(cd))
        if quantized:
            return x2, (kc, vc, ksc, vsc)
        return x2, (kc, vc)

    if quantized:
        xs = (params["blocks"], cache["k"], cache["v"],
              cache["k_scale"], cache["v_scale"])
        x, (ks, vs, kss, vss) = jax.lax.scan(body, x, xs)
        upd = {"k": ks, "v": vs, "k_scale": kss, "v_scale": vss}
    else:
        x, (ks, vs) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"]))
        upd = {"k": ks, "v": vs}
    return cm.rmsnorm(x, params["final_norm"]).astype(jnp.float32), upd


def decode_step(params, cache, token, cfg: ModelConfig, quant: QuantConfig,
                kv_fmt: str = "none"):
    """Append one token; returns (logits, new cache)."""
    B = token.shape[0]
    pos = cache["pos"]
    h_last, upd = _decode_trunk(params, cache, token,
                                jnp.full((B,), pos, jnp.int32), cfg,
                                quant=quant, kv_fmt=kv_fmt)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"].T
    logits = cm.qlogits(h_last, head, quant_cfg=quant,
                        key=jax.random.fold_in(jax.random.PRNGKey(17),
                                               2 * pos + 1))
    new_cache = dict(upd, pos=pos + 1)
    return logits, new_cache


# --------------------------------------------------------------------------- #
# continuous batching: slot-pool cache + fused masked decode
# --------------------------------------------------------------------------- #
def slot_cache_spec(cfg: ModelConfig, n_slots: int, max_seq: int,
                    kv_fmt: str = "none"):
    """Slot-pool KV cache: like ``kv_cache_spec`` but with per-slot positions.

    The batch axis indexes *slots* (not requests); ``pos`` is a (n_slots,)
    vector so every slot tracks its own sequence length, which is what lets
    requests of different lengths share one fused decode step.  Quantized
    ``kv_fmt`` values swap the K/V arrays for code arrays and add
    per-(slot, token, kv-head) bf16 scale arrays, exactly as in
    ``kv_cache_spec``.
    """
    spec = kv_cache_spec(cfg, n_slots, max_seq, kv_fmt=kv_fmt)
    spec["pos"] = jax.ShapeDtypeStruct((n_slots,), jnp.int32)
    return spec


def decode_slots(params, cache, tokens, active, cfg: ModelConfig,
                 quant: QuantConfig, kv_fmt: str = "none"):
    """One fused decode tick across all slots at per-slot positions.

    ``tokens``: (K,) int32 last token of each slot; ``active``: (K,) bool —
    only active slots advance their position (inactive rows still flow
    through the batched GEMMs, but their cache writes land at a stale
    position that is either masked by ``decode_attend`` or overwritten by
    the next admission's prefill, so they cannot perturb live slots).

    For a slot at position ``p`` this computes exactly what ``decode_step``
    computes for a row of a lockstep batch at ``pos == p`` (they share
    ``_decode_trunk``); the quantized-logits key ``fold_in(PRNGKey(17),
    2p + 1)`` is evaluated per slot on its own (1, d) hidden row so the
    draw is bit-identical to the oneshot driver's.
    """
    pos = cache["pos"]                                   # (K,)
    h_last, upd = _decode_trunk(params, cache, tokens, pos, cfg,
                                quant=quant, kv_fmt=kv_fmt)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"].T
    if quant is None or quant.fmt == "none":
        logits = cm.qlogits(h_last, head, quant_cfg=quant,
                            key=jax.random.PRNGKey(0))   # key unused
    else:
        # per-slot quantized logits: each slot's (1, d) row goes through
        # the dispatcher with its own position-derived key, matching the
        # oneshot decode_step draw for that position bit-for-bit; vmap
        # batches the K rows into one dispatch with identical bits
        keys = jax.vmap(lambda p: jax.random.fold_in(
            jax.random.PRNGKey(17), 2 * p + 1))(pos)
        logits = jax.vmap(
            lambda hrow, k: cm.qlogits(hrow[None], head, quant_cfg=quant,
                                       key=k)[0])(h_last, keys)
    new_cache = dict(upd, pos=pos + active.astype(jnp.int32))
    return logits, new_cache


# --------------------------------------------------------------------------- #
# registry glue
# --------------------------------------------------------------------------- #
def _dense_batch_spec(cfg: ModelConfig):
    def spec(batch: int, seq: int):
        return {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    return spec


def _dense_batch_axes(cfg: ModelConfig):
    def axes():
        return {"tokens": ("batch", "seq")}
    return axes


@register_family("dense_lm")
def build_dense_lm(cfg: ModelConfig, quant: QuantConfig) -> Model:
    return Model(
        config=cfg, quant=quant,
        init=functools.partial(init_params, cfg=cfg),
        param_axes=lambda: param_axes(cfg),
        loss_fn=functools.partial(lm_loss, cfg=cfg, quant=quant),
        batch_spec=_dense_batch_spec(cfg),
        batch_axes=_dense_batch_axes(cfg),
        prefill=functools.partial(prefill, cfg=cfg, quant=quant),
        decode_step=functools.partial(decode_step, cfg=cfg, quant=quant),
        cache_spec=functools.partial(kv_cache_spec, cfg),
        cache_axes=lambda **kw: kv_cache_axes(cfg, **kw),
        decode_slots=functools.partial(decode_slots, cfg=cfg, quant=quant),
        slot_cache_spec=functools.partial(slot_cache_spec, cfg),
        kv_formats=("none", "int8", "luq_fp4"),
        per_example_loss=functools.partial(lm_loss, cfg=cfg, quant=quant,
                                           per_example=True),
        ghost_mask=ghost_mask,
        ghost_aux=functools.partial(make_ghost_aux, cfg=cfg, quant=quant),
    )
