"""VLM backbone (internvl2-1b assignment): decoder LM + vision-embed stub.

Per the assignment the InternViT frontend is a STUB — the batch carries
precomputed patch embeddings ``vision_embeds (B, n_vision_tokens, d_model)``
which replace the first ``n_vision_tokens`` positions of the token embedding
sequence.  Loss is masked over the vision prefix.  Everything else reuses the
dense GQA transformer (repro.models.transformer).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, QuantConfig
from repro.models import transformer as tfm
from repro.models.registry import Model, register_family


@register_family("vlm")
def build_vlm(cfg: ModelConfig, quant: QuantConfig) -> Model:
    nv = cfg.n_vision_tokens

    def loss_fn(params, batch, rng, qflags):
        return tfm.lm_loss(params, batch, rng, qflags, cfg=cfg, quant=quant,
                           loss_mask_prefix=nv)

    def batch_spec(batch: int, seq: int):
        return {
            "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "vision_embeds": jax.ShapeDtypeStruct(
                (batch, nv, cfg.d_model), jnp.dtype(cfg.compute_dtype)),
        }

    def batch_axes():
        return {"tokens": ("batch", "seq"),
                "vision_embeds": ("batch", None, "embed")}

    return Model(
        config=cfg, quant=quant,
        init=functools.partial(tfm.init_params, cfg=cfg),
        param_axes=lambda: tfm.param_axes(cfg),
        loss_fn=loss_fn,
        batch_spec=batch_spec,
        batch_axes=batch_axes,
        prefill=functools.partial(tfm.prefill, cfg=cfg, quant=quant),
        decode_step=functools.partial(tfm.decode_step, cfg=cfg, quant=quant),
        cache_spec=functools.partial(tfm.kv_cache_spec, cfg),
        cache_axes=lambda: tfm.kv_cache_axes(cfg),
    )
