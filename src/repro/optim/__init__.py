from repro.optim.optimizers import (
    Optimizer, sgd, momentum, adam, adamw, make_optimizer, apply_updates)
from repro.optim.schedule import make_schedule

__all__ = ["Optimizer", "sgd", "momentum", "adam", "adamw",
           "make_optimizer", "apply_updates", "make_schedule"]
