"""Minimal pure-JAX optimizers (no optax offline).

API mirrors optax: ``opt.init(params) -> state``;
``opt.update(grads, state, params, lr) -> (updates, state)``; apply with
``apply_updates``. All states are pytrees -> checkpoint/shard transparently
(optimizer moments inherit the parameter logical axes in the partitioner).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import OptimConfig

tmap = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable   # (grads, state, params, lr) -> (updates, state)
    name: str = "opt"


def apply_updates(params, updates):
    return tmap(lambda p, u: (p + u).astype(p.dtype), params, updates)


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        return tmap(lambda g: -lr * g, grads), state

    return Optimizer(init, update, "sgd")


def momentum(mu: float = 0.9) -> Optimizer:
    def init(params):
        return tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params, lr):
        new_v = tmap(lambda v, g: mu * v + g, state, grads)
        return tmap(lambda v: -lr * v, new_v), new_v

    return Optimizer(init, update, "momentum")


class AdamState(NamedTuple):
    mu: object
    nu: object
    count: jax.Array


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, name: str = "adam") -> Optimizer:
    def init(params):
        z = lambda: tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamState(z(), z(), jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr):
        count = state.count + 1
        mu = tmap(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = tmap(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                  state.nu, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(m, v, p):
            step = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return -lr * step

        return tmap(upd, mu, nu, params), AdamState(mu, nu, count)

    return Optimizer(init, update, name)


def adamw(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    return adam(b1, b2, eps, weight_decay, name="adamw")


def make_optimizer(cfg: OptimConfig) -> Optimizer:
    if cfg.name == "sgd":
        return sgd() if cfg.momentum == 0.0 else momentum(cfg.momentum)
    if cfg.name == "momentum":
        return momentum(cfg.momentum or 0.9)
    if cfg.name == "adam":
        return adam(cfg.beta1, cfg.beta2, cfg.eps)
    if cfg.name == "adamw":
        return adamw(cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay)
    raise ValueError(f"unknown optimizer {cfg.name}")
