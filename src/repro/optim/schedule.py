"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp

from repro.config import OptimConfig


def make_schedule(cfg: OptimConfig, total_steps: int):
    base = cfg.lr
    warm = max(cfg.warmup_steps, 0)

    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        lr = jnp.asarray(base, jnp.float32)
        if warm > 0:
            lr = lr * jnp.minimum(1.0, (step + 1) / warm)
        if cfg.schedule == "cosine":
            frac = jnp.clip((step - warm) / max(total_steps - warm, 1), 0, 1)
            lr = lr * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        elif cfg.schedule == "linear":
            frac = jnp.clip((step - warm) / max(total_steps - warm, 1), 0, 1)
            lr = lr * (1 - frac)
        return lr

    return sched
