from repro.parallel.axes import logical_constraint, partitioning_context
from repro.parallel.partitioner import (
    DEFAULT_RULES, assign_spec, merge_rules, named_sharding, tree_shardings)
from repro.parallel.collectives import compressed_psum_pods

__all__ = ["logical_constraint", "partitioning_context", "DEFAULT_RULES",
           "assign_spec", "merge_rules", "named_sharding", "tree_shardings",
           "compressed_psum_pods"]
