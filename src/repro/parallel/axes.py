"""Logical axis names + an ambient constraint context.

Models annotate activations with *logical* names; when a partitioning context
is active (set by launch/steps.py under a mesh) the names resolve to
``jax.lax.with_sharding_constraint``; otherwise they are no-ops, so the same
model code runs unsharded on CPU tests.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax

# canonical logical axes
BATCH = "batch"
SEQ = "seq"
EMBED = "embed"
HEADS = "heads"
KV_HEADS = "kv_heads"
HEAD_DIM = "head_dim"
MLP = "mlp"
VOCAB = "vocab"
EXPERTS = "experts"
EXPERT_MLP = "expert_mlp"
LAYERS = "layers"
KV_SEQ = "kv_seq"
STATE = "state"
CONV = "conv"
POD_CHUNK = "pod_chunk"

_ctx = threading.local()


@contextlib.contextmanager
def partitioning_context(resolver):
    """``resolver(logical_names) -> NamedSharding`` or None."""
    prev = getattr(_ctx, "resolver", None)
    _ctx.resolver = resolver
    try:
        yield
    finally:
        _ctx.resolver = prev


def logical_constraint(x: jax.Array, *names: Optional[str]) -> jax.Array:
    resolver = getattr(_ctx, "resolver", None)
    if resolver is None:
        return x
    sharding = resolver(tuple(names), x.shape)
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)
