"""Distributed-optimization collectives.

``compressed_psum_pods``: int8-compressed all-reduce over the ``pod`` axis.
Cross-pod links (data-center interconnect) are the scarcest bandwidth at
multi-pod scale; DP-SGD gradients are unusually compressible *because* they
are already dominated by injected Gaussian noise (the same observation that
lets Youn et al. 2023 use quantization as the DP mechanism itself).  Each
chunk is quantized to int8 with a per-chunk max-abs scale + stochastic
rounding (unbiased), psum'd over pods, and dequantized — 4x fewer cross-pod
bytes than an f32 ring all-reduce, visible in the dry-run HLO's
collective sizes.

Implemented with ``jax.shard_map`` over the full mesh: the gradient enters
with a leading ``pods`` dim (one partial sum per pod, sharded over "pod");
inside the body we quantize the local shard, ``psum`` over "pod", and
dequantize.  All other dims keep their existing (model/data) sharding.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.5 exposes shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map


def compat_shard_map(f, mesh: Mesh, in_specs, out_specs):
    """``shard_map`` across JAX versions, replication checking disabled.

    The replication-check kwarg was renamed (``check_rep`` ->
    ``check_vma``) across releases and the bodies we wrap (vmapped
    custom-VJP hooks, scans, psums) are outside what older checkers can
    prove; callers guarantee replicated outputs themselves (psum /
    tiled all_gather).  Used by the sharded ghost driver
    (``repro.dp.ghost.sharded_ghost_clipped_grad_sum``).
    """
    for kw in ("check_rep", "check_vma"):
        try:
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **{kw: False})
        except TypeError:
            continue
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def _quantize_int8(x, key):
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.where(scale > 0, scale, 1.0)
    y = x / scale
    lo = jnp.floor(y)
    frac = y - lo
    u = jax.random.uniform(key, x.shape)
    q = lo + (u < frac)
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def compressed_psum_pods(partials, mesh: Mesh, seed: jax.Array,
                         param_specs):
    """Reduce a pytree of per-pod partial gradients over the "pod" axis.

    ``partials`` leaves have a leading ``pods`` dim sharded over "pod";
    ``param_specs`` is the matching pytree of PartitionSpecs WITHOUT the pods
    dim.  Returns the reduced tree (pods dim removed, replicated over pod).
    """
    leaves, treedef = jax.tree_util.tree_flatten(partials)
    spec_leaves = treedef.flatten_up_to(param_specs)

    out = []
    for i, (leaf, spec) in enumerate(zip(leaves, spec_leaves)):
        in_spec = P("pod", *spec)
        out_spec = P(*spec)

        def body(x, *, _i=i):
            x = x[0].astype(jnp.float32)               # local pod partial
            k = jax.random.fold_in(jax.random.PRNGKey(0),
                                   jnp.uint32(_i) + seed)
            # shared scale across pods (scalar pmax — negligible wire cost)
            # so the int8 sum dequantizes exactly
            local_scale = jnp.max(jnp.abs(x)) / 127.0
            scale = jax.lax.pmax(local_scale, "pod")
            scale = jnp.where(scale > 0, scale, 1.0)
            y = x / scale
            lo = jnp.floor(y)
            u = jax.random.uniform(k, x.shape)
            q = jnp.clip(lo + (u < (y - lo)), -127, 127).astype(jnp.int8)
            qsum = jax.lax.psum(q.astype(jnp.int32), "pod")
            return qsum.astype(jnp.float32) * scale

        fn = _shard_map(body, mesh=mesh, in_specs=(in_spec,),
                        out_specs=out_spec)
        out.append(fn(leaf))
    return jax.tree_util.tree_unflatten(treedef, out)
