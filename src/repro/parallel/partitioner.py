"""Logical-axis partitioner with divisibility fallback.

Every tensor (params, activations, caches, batches) carries a tuple of
logical axis names.  A *rule table* maps each name to an ordered list of
mesh-axis candidates; per tensor, dims are assigned greedily in order:

  * a candidate is a tuple of mesh axes (e.g. ``("pod", "data")``);
  * it is taken iff all its axes exist in the mesh, none are already used by
    this tensor, and their size product divides the dim;
  * otherwise the next candidate is tried; no candidate -> dim unsharded.

This single mechanism yields DP/TP/EP/SP layouts across all 10 architectures
(DESIGN.md §5): e.g. a KV cache rule list ``kv_heads->model`` then
``kv_seq->model`` automatically produces head-parallel decode for MHA archs
and sequence-parallel (flash-decoding style) for GQA archs whose kv count
doesn't divide the TP degree.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Candidate = Tuple[str, ...]
Rules = Dict[str, Tuple[Candidate, ...]]

# ordered candidates per logical axis name
DEFAULT_RULES: Rules = {
    "batch": (("pod", "data"), ("data",)),
    "vocab": (("model",),),
    "embed": (),
    "mlp": (("model",),),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "head_dim": (),
    "experts": (("model",),),
    "expert_mlp": (),
    "layers": (),
    "seq": (),
    "kv_seq": (("model",),),       # fallback after kv_heads (greedy order)
    "state": (),
    "conv": (),
}


def merge_rules(base: Rules, overrides: Sequence[Tuple[str, Tuple[Candidate, ...]]]) -> Rules:
    rules = dict(base)
    for name, cands in overrides:
        rules[name] = tuple(tuple(c) for c in cands)
    return rules


def assign_spec(logical: Sequence[Optional[str]], shape: Sequence[int],
                mesh: Mesh, rules: Rules) -> P:
    """Greedy mesh-axis assignment for one tensor."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used = set()
    entries = []
    if len(logical) != len(shape):
        raise ValueError(f"logical axes {logical} rank != shape {shape}")
    for name, dim in zip(logical, shape):
        chosen = None
        for cand in rules.get(name, ()) if name else ():
            if not cand:
                continue
            if any(a not in axis_sizes for a in cand):
                continue
            if any(a in used for a in cand):
                continue
            prod = 1
            for a in cand:
                prod *= axis_sizes[a]
            if prod == 0 or dim % prod != 0:
                continue
            chosen = cand
            break
        if chosen is None:
            entries.append(None)
        else:
            used.update(chosen)
            entries.append(chosen if len(chosen) > 1 else chosen[0])
    return P(*entries)


def named_sharding(logical, shape, mesh: Mesh, rules: Rules) -> NamedSharding:
    return NamedSharding(mesh, assign_spec(logical, shape, mesh, rules))


def tree_shardings(axes_tree, abstract_tree, mesh: Mesh, rules: Rules):
    """Map (axes pytree, ShapeDtypeStruct pytree) -> NamedSharding pytree."""
    def one(axes, ab):
        if axes is None or ab.ndim == 0:
            # scalar or explicitly unannotated -> replicated
            return NamedSharding(mesh, P())
        return named_sharding(axes, ab.shape, mesh, rules)

    return jax.tree_util.tree_map(
        one, axes_tree, abstract_tree,
        is_leaf=lambda x: x is None or (isinstance(x, tuple) and len(x) > 0
                                        and all(isinstance(e, (str, type(None)))
                                                for e in x)))


def activation_resolver(mesh: Mesh, rules: Rules):
    """Resolver for models' ``logical_constraint`` annotations."""
    def resolve(names, shape):
        try:
            return named_sharding(names, shape, mesh, rules)
        except ValueError:
            return None
    return resolve


def apply_spec_tree(tree, axes_tree, mesh, rules):
    """with_sharding_constraint over a pytree using logical axes."""
    sh = tree_shardings(axes_tree, jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree), mesh, rules)
    return jax.tree_util.tree_map(jax.lax.with_sharding_constraint, tree, sh)
