from repro.quant.formats import (
    make_quantizer,
    format_bits,
    luq_fp4,
    int4_uniform,
    fp8_e4m3,
    fp8_e5m2,
    STOCHASTIC_FORMATS,
)
from repro.quant.fake_quant import qeinsum, qconv2d
from repro.quant.backend import (
    BACKENDS,
    capability_table,
    get_clip_sum,
    get_matmul,
    get_quantizer,
    resolve_backend,
    supported,
)

__all__ = [
    "make_quantizer", "format_bits", "luq_fp4", "int4_uniform",
    "fp8_e4m3", "fp8_e5m2", "STOCHASTIC_FORMATS", "qeinsum", "qconv2d",
    "BACKENDS", "capability_table", "get_clip_sum", "get_matmul",
    "get_quantizer", "resolve_backend", "supported",
]
