from repro.quant.formats import (
    make_quantizer,
    format_bits,
    luq_fp4,
    int4_uniform,
    fp8_e4m3,
    fp8_e5m2,
    STOCHASTIC_FORMATS,
)
from repro.quant.fake_quant import qeinsum, qconv2d

__all__ = [
    "make_quantizer", "format_bits", "luq_fp4", "int4_uniform",
    "fp8_e4m3", "fp8_e5m2", "STOCHASTIC_FORMATS", "qeinsum", "qconv2d",
]
