"""Quantizer-backend dispatch: (op, format) -> implementation registry.

The quantization stack has two execution backends:

``"ref"``     the pure-jnp quantizers in ``repro.quant.formats`` (default;
              runs everywhere, the numerical reference),
``"pallas"``  the fused Pallas TPU kernels wrapped in ``repro.kernels.ops``
              (interpret mode on CPU, compiled on real TPUs — see
              ``REPRO_PALLAS_INTERPRET`` in kernels/ops.py).

Three ops are dispatched:

``"quantize"``  ``q(x, key) -> x_q`` — elementwise fake-quantization, the
                primitive behind ``fake_quant.qeinsum``/``qconv2d``.
``"matmul"``    ``mm(a, b, key) -> f32`` — quantize-both-operands matmul
                (serving hot path); the pallas impl quantizes tiles in VMEM
                fused with the MXU contraction (zero extra HBM traffic).
``"clip_sum"``  ``cs(grads, clip_norm) -> (clipped_sum, norms)`` — fused DP
                per-example clip + batch sum over (B, D) gradient rows;
                format-agnostic (registered under fmt ``"*"``).
``"ghost_norm"`` ``gn(xmat, gmat, key_x, key_g) -> f32 scalar`` — the ghost
                clipping tap ``||Q(x)^T Q(g)||_F^2`` from the (T, Din) /
                (T, Dout) wgrad-GEMM matrix views; the pallas impl fuses
                quantize + Gram + tap-reduce into one VMEM pass
                (``repro.kernels.ghost_norm``), the ref impl composes the
                quantizer with the mixed-ghost-norm reduction.
``"kv_quant"``  ``kvq(x) -> (codes, scales)`` — deterministic per-row
                quantization of written K/V cache rows (serve path;
                formats are the KV *storage* formats ``none|int8|luq_fp4``
                of ``repro.quant.kv_cache``, not the training formats);
                the pallas impl fuses amax + scale + encode into one VMEM
                pass per row block (``repro.kernels.decode_attn``).
``"decode_attn"`` ``attn(q, kc, vc, ks, vs, pos, *, n_kv, scale) -> ctx``
                — one-token GQA attention over the quantized slot-pool
                cache; the pallas impl fuses dequantization into the QK
                and PV contractions with per-slot position masking and
                softmax in one VMEM pass per (slot, kv-head); the ref
                impl dequantizes and runs the plain-jnp attention (for
                ``none`` it IS the historical ``decode_attend`` math,
                bit-for-bit).

Backend selection: the ``REPRO_QUANT_BACKEND`` environment variable
overrides everything (so CI can force the pallas leg without touching
configs); otherwise the per-call request (``QuantConfig.backend``) wins;
otherwise ``"ref"``.  Formats a backend does not implement fall back to
``"ref"`` *explicitly*: ``get_*`` returns ``(impl, actual_backend)`` so
callers can see (and tests can assert) where an op really runs.
"""
from __future__ import annotations

import os
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.quant import formats

ENV_VAR = "REPRO_QUANT_BACKEND"
DEFAULT_BACKEND = "ref"
BACKENDS = ("ref", "pallas")
OPS = ("quantize", "matmul", "clip_sum", "ghost_norm", "kv_quant",
       "decode_attn")

# fmt sentinel for format-agnostic ops (clip_sum)
ANY_FORMAT = "*"

# (op, fmt, backend) -> impl
_REGISTRY: Dict[Tuple[str, str, str], Callable] = {}


def register(op: str, fmt: str, backend: str, impl: Callable) -> None:
    if op not in OPS:
        raise ValueError(f"unknown op {op!r} (expected one of {OPS})")
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    _REGISTRY[(op, fmt, backend)] = impl


def _lookup(op: str, fmt: str, backend: str):
    impl = _REGISTRY.get((op, fmt, backend))
    if impl is None:
        impl = _REGISTRY.get((op, ANY_FORMAT, backend))
    return impl


def supported(op: str, fmt: str, backend: str) -> bool:
    """Capability check: does ``backend`` natively implement (op, fmt)?"""
    return _lookup(op, fmt, backend) is not None


def resolve_backend(requested: str | None = None) -> str:
    """Concrete backend name: env override > request > default."""
    backend = os.environ.get(ENV_VAR) or requested or DEFAULT_BACKEND
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown quant backend {backend!r} (expected one of {BACKENDS}; "
            f"check {ENV_VAR} / QuantConfig.backend)")
    return backend


def get_impl(op: str, fmt: str, backend: str | None = None):
    """Resolve (op, fmt) on ``backend`` with explicit ref fallback.

    Returns ``(impl, actual_backend)``; ``actual_backend`` differs from the
    request when the backend lacks the format and ``"ref"`` filled in.
    """
    be = resolve_backend(backend)
    impl = _lookup(op, fmt, be)
    if impl is None and be != DEFAULT_BACKEND:
        impl, be = _lookup(op, fmt, DEFAULT_BACKEND), DEFAULT_BACKEND
    if impl is None:
        raise KeyError(f"no implementation for op={op!r} fmt={fmt!r} "
                       f"on any backend")
    return impl, be


def get_quantizer(fmt: str, backend: str | None = None):
    """``(q(x, key) -> x_q, actual_backend)``."""
    return get_impl("quantize", fmt, backend)


def get_matmul(fmt: str, backend: str | None = None):
    """``(mm(a, b, key) -> (M, N) f32, actual_backend)``."""
    return get_impl("matmul", fmt, backend)


def get_kv_quant(fmt: str, backend: str | None = None):
    """``(kvq(x) -> (codes, scales), actual_backend)`` — KV cache rows.

    ``fmt`` is a KV *storage* format (``repro.config.KV_CACHE_FORMATS``),
    orthogonal to the training formats the other ops use.
    """
    return get_impl("kv_quant", fmt, backend)


def get_decode_attn(fmt: str, backend: str | None = None):
    """``(attn(q, kc, vc, ks, vs, pos, *, n_kv, scale), actual_backend)``."""
    return get_impl("decode_attn", fmt, backend)


def get_clip_sum(backend: str | None = None):
    """``(cs(grads, clip_norm) -> (clipped_sum, norms), actual_backend)``.

    Accepts the DPConfig spelling ``"fused"`` as an alias for ``"pallas"``.
    Unlike the quantize/matmul ops, ``REPRO_QUANT_BACKEND`` does NOT apply
    here: the clip implementation is its own knob (``DPConfig.clip_backend``)
    and an explicit ``"fused"`` request must not be silently downgraded by
    an env var meant to pin the quantizers.
    """
    if backend == "fused":
        backend = "pallas"
    be = backend or DEFAULT_BACKEND
    if be not in BACKENDS:
        raise ValueError(f"unknown clip backend {be!r} "
                         f"(expected one of {BACKENDS})")
    impl = _lookup("clip_sum", ANY_FORMAT, be)
    if impl is None:
        raise KeyError(f"no clip_sum implementation on backend {be!r}")
    return impl, be


def capability_table() -> Dict[str, Dict[str, Tuple[str, ...]]]:
    """{op: {backend: (natively supported formats...)}} — docs/tests."""
    table: Dict[str, Dict[str, list]] = {op: {b: [] for b in BACKENDS}
                                         for op in OPS}
    for (op, fmt, backend) in _REGISTRY:
        table[op][backend].append(fmt)
    return {op: {b: tuple(sorted(fmts)) for b, fmts in row.items()}
            for op, row in table.items()}


# --------------------------------------------------------------------------- #
# ref backend: the pure-jnp formats (every format, every op)
# --------------------------------------------------------------------------- #
def _ref_matmul(fmt: str) -> Callable:
    q = formats.make_quantizer(fmt)

    def mm(a, b, key):
        ka, kb = jax.random.split(key)
        aq = q(a, ka).astype(jnp.float32)
        bq = q(b, kb).astype(jnp.float32)
        return aq @ bq

    return mm


def _ref_clip_sum(grads, clip_norm):
    from repro.kernels.ref import per_sample_clip_ref
    return per_sample_clip_ref(grads, clip_norm)


def _ref_ghost_norm(fmt: str) -> Callable:
    q = formats.make_quantizer(fmt)

    def gn(xmat, gmat, key_x, key_g):
        # lazy: dp.ghost imports this module only inside functions, so the
        # package stays import-order independent
        from repro.dp.ghost import _matpair_sq_norm
        return _matpair_sq_norm(q(xmat, key_x), q(gmat, key_g))

    return gn


def _ref_kv_quant(fmt: str) -> Callable:
    def kvq(x):
        from repro.quant import kv_cache
        return kv_cache.kv_quant(fmt, x)

    return kvq


def _ref_decode_attn(fmt: str) -> Callable:
    def attn(q, kc, vc, ks, vs, pos, *, n_kv, scale):
        from repro.quant import kv_cache
        return kv_cache.ref_decode_attn(fmt, q, kc, vc, ks, vs, pos,
                                        n_kv=n_kv, scale=scale)

    return attn


for _fmt in formats._FORMATS:
    register("quantize", _fmt, "ref", formats.make_quantizer(_fmt))
    register("matmul", _fmt, "ref", _ref_matmul(_fmt))
    register("ghost_norm", _fmt, "ref", _ref_ghost_norm(_fmt))
register("clip_sum", ANY_FORMAT, "ref", _ref_clip_sum)
# KV-cache ops use the storage formats (repro.config.KV_CACHE_FORMATS),
# not the training formats above — "int8" exists only here.
for _fmt in ("none", "int8", "luq_fp4"):
    register("kv_quant", _fmt, "ref", _ref_kv_quant(_fmt))
    register("decode_attn", _fmt, "ref", _ref_decode_attn(_fmt))


# --------------------------------------------------------------------------- #
# pallas backend: the fused TPU kernels (LUQ-FP4 only; clip is any-format)
# --------------------------------------------------------------------------- #
# Kernel wrappers are imported lazily inside the impls: repro.kernels pulls
# repro.quant.formats back in, and deferring the import keeps package init
# order-independent.
def _pallas_quantize(x, key):
    from repro.kernels.ops import luq_quantize
    return luq_quantize(x, key)


def _pallas_matmul(a, b, key):
    from repro.kernels.ops import luq_matmul
    return luq_matmul(a, b, key)


def _pallas_clip_sum(grads, clip_norm):
    from repro.kernels.ops import clip_and_sum
    return clip_and_sum(grads, float(clip_norm))


def _pallas_ghost_norm(xmat, gmat, key_x, key_g):
    from repro.kernels.ops import ghost_norm_sq
    return ghost_norm_sq(xmat, gmat, key_x, key_g)


def _pallas_kv_quant(fmt: str) -> Callable:
    def kvq(x):
        from repro.kernels.ops import kv_quant_rows
        return kv_quant_rows(x, fmt)

    return kvq


def _pallas_decode_attn(fmt: str) -> Callable:
    def attn(q, kc, vc, ks, vs, pos, *, n_kv, scale):
        from repro.kernels.ops import decode_attn_fused
        return decode_attn_fused(q, kc, vc, ks, vs, pos, fmt=fmt,
                                 n_kv=n_kv, scale=scale)

    return attn


register("quantize", "luq_fp4", "pallas", _pallas_quantize)
register("matmul", "luq_fp4", "pallas", _pallas_matmul)
register("clip_sum", ANY_FORMAT, "pallas", _pallas_clip_sum)
register("ghost_norm", "luq_fp4", "pallas", _pallas_ghost_norm)
# kv_fmt="none" has no fused kernel (there is nothing to dequantize);
# it falls back to ref explicitly via get_impl, like every missing format
for _fmt in ("int8", "luq_fp4"):
    register("kv_quant", _fmt, "pallas", _pallas_kv_quant(_fmt))
    register("decode_attn", _fmt, "pallas", _pallas_decode_attn(_fmt))
