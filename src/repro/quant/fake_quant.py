"""Fake-quantized GEMM/conv primitives (paper A.12, Fig. 7).

The paper's simulation quantizes the inputs of all three GEMMs of a layer:

    forward :  y  = Q(x)  . Q(w)
    dgrad   :  dx = Q(g)  . Q(w)^T
    wgrad   :  dw = Q(x)^T . Q(g)

We implement this once, generically, with ``jax.custom_vjp``: the backward
GEMMs are derived mechanically from the forward contraction via
``jax.linear_transpose``, so the same primitive serves einsums of any
rank (dense, QKV projections, MoE expert matmuls) and convolutions.

Policy flags are *traced* scalars: ``flag`` in {0., 1.} selects the quantized
or the full-precision path via ``lax.cond`` — switching the DPQuant policy
never triggers recompilation (flags are just inputs).

Randomness: stochastic formats consume explicit uint32 seeds; each GEMM input
gets an independent fold so forward/dgrad/wgrad re-quantizations are
independent draws, as in LUQ.  ``seed`` and ``fold`` are folded into the key
*separately* — a combined ``seed + fold`` would make (seed=s, fold=1) collide
with (seed=s+1, fold=0), correlating draws across adjacent steps/GEMMs.

``backend`` selects the quantizer implementation through
``repro.quant.backend`` ("ref" jnp formats or the "pallas" fused kernels);
the ``REPRO_QUANT_BACKEND`` env var overrides it globally.

Ghost-clipping integration (``repro.dp.ghost``): when a ghost context is
active at trace time, ``qeinsum``/``qconv2d`` route to the ghost-tapped
custom-VJP variants (norm pass) or enable per-example quantization
semantics on the batched activation/cotangent operands (grad pass) — see
the module docstring of ``repro.dp.ghost`` for the parity argument.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.quant import backend as qbackend


def _maybe_quant(x, seed: jax.Array, fold: int, fmt: str, flag: jax.Array,
                 backend: str = "ref", per_example: bool = False):
    """Quantize ``x`` when ``flag > 0.5``, else pass through. ``seed`` uint32.

    ``per_example=True`` (ghost grad pass, batched operands only) applies
    the quantizer to each (1, ...) example slice with the shared key —
    per-example max scaling and hoisted draws, bit-matching the vmap DP
    path's per-lane quantization (repro.dp.ghost.per_example_quantizer).
    """
    if fmt == "none":
        return x
    q, _ = qbackend.get_quantizer(fmt, backend)
    if per_example:
        from repro.dp.ghost import per_example_quantizer
        q = per_example_quantizer(q)

    def do_q(v):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(0), seed), fold)
        return q(v, key)

    return jax.lax.cond(flag > 0.5, do_q, lambda v: v, x)


@functools.lru_cache(maxsize=None)
def _make_qeinsum(spec: str, fmt: str, q_fwd: bool, q_dgrad: bool,
                  q_wgrad: bool, backend: str, per_example: bool = False):
    """Build a custom-VJP einsum with quantized fwd/dgrad/wgrad GEMM inputs.

    ``per_example`` switches the *batched* operands (activation ``x`` and
    cotangent ``g`` — never the weight) to per-example quantization for
    the ghost grad pass.
    """

    def einsum(x, w):
        return jnp.einsum(spec, x, w)

    @jax.custom_vjp
    def qeinsum(x, w, seed, flag):
        xq = (_maybe_quant(x, seed, 0, fmt, flag, backend, per_example)
              if q_fwd else x)
        wq = _maybe_quant(w, seed, 1, fmt, flag, backend) if q_fwd else w
        return einsum(xq, wq)

    def fwd(x, w, seed, flag):
        return qeinsum(x, w, seed, flag), (x, w, seed, flag)

    def bwd(res, g):
        x, w, seed, flag = res
        # dgrad: dx = GEMM(Q(g), Q(w)) via the transpose of y = einsum(x, w).
        wq = _maybe_quant(w, seed, 2, fmt, flag, backend) if q_dgrad else w
        gq_d = (_maybe_quant(g, seed, 3, fmt, flag, backend, per_example)
                if q_dgrad else g)
        dx_fn = jax.linear_transpose(lambda t: einsum(t, wq), x)
        (dx,) = dx_fn(gq_d)
        # wgrad: dw = GEMM(Q(x), Q(g)).
        xq = (_maybe_quant(x, seed, 4, fmt, flag, backend, per_example)
              if q_wgrad else x)
        gq_w = (_maybe_quant(g, seed, 5, fmt, flag, backend, per_example)
                if q_wgrad else g)
        dw_fn = jax.linear_transpose(lambda t: einsum(xq, t), w)
        (dw,) = dw_fn(gq_w)
        return dx, dw, None, None

    qeinsum.defvjp(fwd, bwd)
    return qeinsum


def qeinsum(spec: str, x: jax.Array, w: jax.Array, *, seed: jax.Array,
            flag: jax.Array, fmt: str = "luq_fp4",
            q_fwd: bool = True, q_dgrad: bool = True, q_wgrad: bool = True,
            backend: str = None):
    """Quantization-aware einsum. ``flag`` and ``seed`` are traced scalars."""
    # Resolve env override *before* the lru_cache key so flipping
    # REPRO_QUANT_BACKEND mid-process cannot serve a stale closure.
    backend = qbackend.resolve_backend(backend)
    seed = jnp.asarray(seed, jnp.uint32)
    flag = jnp.asarray(flag, jnp.float32)
    from repro.dp import ghost
    ctx = ghost.current()
    if ctx is not None and ctx.mode == "norm":
        fn = ghost.make_ghost_qeinsum(spec, fmt, q_fwd, q_dgrad, q_wgrad,
                                      backend)
        return fn(x, w, seed, flag, ctx.tap)
    per_example = ctx is not None and ctx.mode == "grad"
    fn = _make_qeinsum(spec, fmt, q_fwd, q_dgrad, q_wgrad, backend,
                       per_example)
    return fn(x, w, seed, flag)


@functools.lru_cache(maxsize=None)
def _make_qconv(fmt: str, q_fwd: bool, q_dgrad: bool, q_wgrad: bool,
                strides: tuple, padding: str, dnums_key: tuple, backend: str,
                per_example: bool = False, rhs_dilation: tuple = (1, 1),
                feature_groups: int = 1):
    dn = jax.lax.ConvDimensionNumbers(*dnums_key)

    def conv(x, w):
        return jax.lax.conv_general_dilated(
            x, w, strides, padding, rhs_dilation=rhs_dilation,
            dimension_numbers=dn, feature_group_count=feature_groups)

    @jax.custom_vjp
    def qconv(x, w, seed, flag):
        xq = (_maybe_quant(x, seed, 0, fmt, flag, backend, per_example)
              if q_fwd else x)
        wq = _maybe_quant(w, seed, 1, fmt, flag, backend) if q_fwd else w
        return conv(xq, wq)

    def fwd(x, w, seed, flag):
        return qconv(x, w, seed, flag), (x, w, seed, flag)

    def bwd(res, g):
        x, w, seed, flag = res
        wq = _maybe_quant(w, seed, 2, fmt, flag, backend) if q_dgrad else w
        gq_d = (_maybe_quant(g, seed, 3, fmt, flag, backend, per_example)
                if q_dgrad else g)
        dx_fn = jax.linear_transpose(lambda t: conv(t, wq), x)
        (dx,) = dx_fn(gq_d)
        xq = (_maybe_quant(x, seed, 4, fmt, flag, backend, per_example)
              if q_wgrad else x)
        gq_w = (_maybe_quant(g, seed, 5, fmt, flag, backend, per_example)
                if q_wgrad else g)
        dw_fn = jax.linear_transpose(lambda t: conv(xq, t), w)
        (dw,) = dw_fn(gq_w)
        return dx, dw, None, None

    qconv.defvjp(fwd, bwd)
    return qconv


def qconv2d(x: jax.Array, w: jax.Array, *, seed: jax.Array, flag: jax.Array,
            strides=(1, 1), padding="SAME", fmt: str = "luq_fp4",
            q_fwd: bool = True, q_dgrad: bool = True, q_wgrad: bool = True,
            backend: str = None, rhs_dilation=(1, 1), feature_groups: int = 1):
    """Quantization-aware NHWC conv2d (weights HWIO).

    ``rhs_dilation``/``feature_groups`` map to the same-named
    ``lax.conv_general_dilated`` knobs; under ghost norm passes those
    layers use the per-layer direct-norm fallback (the patches unfold
    identity only covers dense undilated convs — see repro.dp.ghost).
    """
    backend = qbackend.resolve_backend(backend)
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape,
                                        ("NHWC", "HWIO", "NHWC"))
    seed = jnp.asarray(seed, jnp.uint32)
    flag = jnp.asarray(flag, jnp.float32)
    from repro.dp import ghost
    ctx = ghost.current()
    if ctx is not None and ctx.mode == "norm":
        fn = ghost.make_ghost_qconv(fmt, q_fwd, q_dgrad, q_wgrad,
                                    tuple(strides), padding, tuple(dn),
                                    tuple(w.shape[:2]), backend,
                                    tuple(rhs_dilation), feature_groups)
        return fn(x, w, seed, flag, ctx.tap)
    per_example = ctx is not None and ctx.mode == "grad"
    fn = _make_qconv(fmt, q_fwd, q_dgrad, q_wgrad, tuple(strides), padding,
                     tuple(dn), backend, per_example, tuple(rhs_dilation),
                     feature_groups)
    return fn(x, w, seed, flag)
