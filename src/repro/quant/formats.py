"""Low-precision quantizer formats.

All quantizers are *unbiased* and *scale-invariant* (the premises of the
paper's Proposition 1), except the deterministic FP8 casts which are
round-to-nearest (the paper's A.9 FP8 ablation uses plain casting).

Implemented formats
-------------------
``luq_fp4``   LUQ-FP4 (Chmiel et al., 2024): 1 sign + 3 exponent bits.
              Values are snapped onto a per-tensor power-of-two grid anchored
              at max|x|; magnitudes below the smallest level are *stochastically
              underflowed* to 0 or the smallest level; magnitudes inside the
              grid are stochastically rounded between adjacent powers of two.
              Unbiased: E[q(x) | x] = x (elementwise).
``int4``      Uniform 4-bit: 15 symmetric levels with stochastic rounding.
``fp8_e4m3``  / ``fp8_e5m2``: ml_dtypes round-trip cast (deterministic).
``bf16``      bfloat16 round-trip cast.
``none``      identity.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

LUQ_EXP_LEVELS = 7   # 3 exponent bits -> 8 codes; one reserved for zero


def _split_sign(x):
    return jnp.sign(x), jnp.abs(x)


def luq_fp4(x: jax.Array, key: jax.Array) -> jax.Array:
    """LUQ FP4 stochastic quantizer (per-tensor max scaling).

    Grid (relative to alpha = max|x|): {0} U {alpha * 2^-k : k = 0..6}.
    """
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    alpha = jnp.max(jnp.abs(xf))
    # Guard all-zero tensors.
    safe_alpha = jnp.where(alpha > 0, alpha, 1.0)
    sign, mag = _split_sign(xf)
    y = mag / safe_alpha                                  # in [0, 1]
    min_level = 2.0 ** (-(LUQ_EXP_LEVELS - 1))            # 2^-6

    u = jax.random.uniform(key, x.shape, jnp.float32)

    # --- underflow branch: |y| < 2^-6 -> {0, 2^-6} stochastically (unbiased)
    p_under = y / min_level
    under = jnp.where(u < p_under, min_level, 0.0)

    # --- log-domain stochastic rounding between adjacent powers of two
    ylog = jnp.log2(jnp.maximum(y, min_level))
    k = jnp.clip(jnp.floor(ylog), -(LUQ_EXP_LEVELS - 1), 0.0)
    low = jnp.exp2(k)
    high = jnp.minimum(jnp.exp2(k + 1.0), 1.0)
    denom = jnp.maximum(high - low, 1e-30)
    p_up = (y - low) / denom
    rounded = jnp.where(u < p_up, high, low)

    q = jnp.where(y < min_level, under, rounded)
    out = sign * q * safe_alpha
    out = jnp.where(alpha > 0, out, 0.0)
    return out.astype(dtype)


def int4_uniform(x: jax.Array, key: jax.Array) -> jax.Array:
    """Uniform symmetric INT4 with stochastic rounding (paper A.9.2).

    16 codes; we use the symmetric grid {-7..7} * Delta, Delta = max|x|/7.
    """
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    alpha = jnp.max(jnp.abs(xf))
    safe_alpha = jnp.where(alpha > 0, alpha, 1.0)
    delta = safe_alpha / 7.0
    y = xf / delta                                        # in [-7, 7]
    lo = jnp.floor(y)
    frac = y - lo
    u = jax.random.uniform(key, x.shape, jnp.float32)
    q = lo + (u < frac).astype(jnp.float32)
    q = jnp.clip(q, -7.0, 7.0)
    out = q * delta
    out = jnp.where(alpha > 0, out, 0.0)
    return out.astype(dtype)


def _cast_roundtrip(x: jax.Array, cast_dtype) -> jax.Array:
    return x.astype(cast_dtype).astype(x.dtype)


def fp8_e4m3(x: jax.Array, key=None) -> jax.Array:
    del key
    return _cast_roundtrip(x, jnp.float8_e4m3fn)


def fp8_e5m2(x: jax.Array, key=None) -> jax.Array:
    del key
    return _cast_roundtrip(x, jnp.float8_e5m2)


def bf16(x: jax.Array, key=None) -> jax.Array:
    del key
    return _cast_roundtrip(x, jnp.bfloat16)


def identity(x: jax.Array, key=None) -> jax.Array:
    del key
    return x


_FORMATS = {
    "luq_fp4": luq_fp4,
    "int4": int4_uniform,
    "fp8_e4m3": fp8_e4m3,
    "fp8_e5m2": fp8_e5m2,
    "bf16": bf16,
    "none": identity,
}

STOCHASTIC_FORMATS = ("luq_fp4", "int4")


def make_quantizer(fmt: str) -> Callable[[jax.Array, jax.Array], jax.Array]:
    """Return ``q(x, key) -> x_q``. Raises KeyError for unknown formats."""
    return _FORMATS[fmt]


def format_bits(fmt: str) -> int:
    return {"luq_fp4": 4, "int4": 4, "fp8_e4m3": 8, "fp8_e5m2": 8,
            "bf16": 16, "none": 32}[fmt]
