"""KV-cache storage quantization: per-row codes + bfloat16 scales.

The serving engines store K/V in a fixed cache and re-read the whole
prefix every decode tick, so the cache dominates serving memory.  This
module defines the *storage* formats of that cache (``ServeConfig.kv_fmt``)
and the pure-jnp reference implementations of the two dispatched ops:

``kv_quant``    quantize a written K/V row ``(..., head_dim)`` into
                ``(codes, scales)`` with one scale per (token, kv-head) row,
``decode_attn`` one-token GQA attention over the quantized cache with
                dequantization folded into the QK and PV contractions.

Formats (``repro.config.KV_CACHE_FORMATS``):

``none``      identity — the cache keeps the model's compute dtype and no
              scales are stored.  The ref impl is bit-identical to the
              plain-jnp ``models.transformer.decode_attend`` math.
``int8``      symmetric round-to-nearest to [-127, 127] with per-row scale
              ``bf16(amax / 127)`` — 4x smaller rows (f32 cache) plus two
              scale bytes per row.
``luq_fp4``   the LUQ 4-bit grid {0} ∪ {±2^-k, k = 0..6} scaled by the
              per-row amax, *deterministic* nearest-level rounding (cache
              storage wants reproducible read-back, not the unbiasedness
              the training quantizers get from stochastic rounding), two
              codes packed per uint8 along head_dim (even index = low
              nibble) — 8x smaller rows.

Scales are stored in **bfloat16** and the quantizers divide by the
bf16-rounded scale (not the exact amax), so dequantization uses exactly
the stored scale — a cache round-trip is deterministic and identical on
every backend, which is what makes engine-vs-oneshot token equivalence
hold per format (docs/SERVING.md "Equivalence contract").

The elementwise encode/decode helpers here are shared with the fused
Pallas kernels (``repro.kernels.decode_attn``) so the two backends cannot
drift numerically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import KV_CACHE_FORMATS

SCALE_DTYPE = jnp.bfloat16
INT8_QMAX = 127.0
# luq_fp4 magnitude grid: code m in 1..7 decodes to 2^(m-7), m = 0 to 0.
FP4_LEVELS = 7


def code_spec(fmt: str, head_dim: int):
    """``(code_dtype, code_dim)`` of one cached row; dtype None = native.

    ``code_dim`` is the stored last-axis width: ``head_dim`` for int8,
    ``head_dim // 2`` for the nibble-packed luq_fp4 codes.
    """
    if fmt == "none":
        return None, head_dim
    if fmt == "int8":
        return jnp.int8, head_dim
    if fmt == "luq_fp4":
        if head_dim % 2:
            raise ValueError(
                f"kv_fmt='luq_fp4' packs two codes per byte along head_dim "
                f"and needs an even head_dim, got {head_dim}")
        return jnp.uint8, head_dim // 2
    raise ValueError(f"unknown kv cache format {fmt!r} "
                     f"(expected one of {KV_CACHE_FORMATS})")


# --------------------------------------------------------------------------- #
# elementwise encode/decode math (shared by the ref impls and the Pallas
# kernels — single source of truth so backends cannot drift)
# --------------------------------------------------------------------------- #
def int8_row_scale(amax: jax.Array) -> jax.Array:
    """Per-row scale, f32 value of the *stored* bf16 scale."""
    return (amax / INT8_QMAX).astype(SCALE_DTYPE).astype(jnp.float32)


def int8_encode(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Round-to-nearest int8 codes (f32 domain); ``scale`` broadcasts on
    the last axis.  A zero scale (all-zero row) encodes to zero codes."""
    safe = jnp.where(scale > 0, scale, 1.0)
    return jnp.clip(jnp.round(x / safe[..., None]), -INT8_QMAX, INT8_QMAX)


def fp4_row_scale(amax: jax.Array) -> jax.Array:
    """luq_fp4 per-row scale = bf16(amax) (the grid's top level is 1.0)."""
    return amax.astype(SCALE_DTYPE).astype(jnp.float32)


def fp4_encode(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Nearest-level luq_fp4 codes 0..15 (f32 domain): sign bit 3, magnitude
    m in bits 0..2 decoding to ``2^(m-7)`` (m = 0 decodes to exactly 0)."""
    safe = jnp.where(scale > 0, scale, 1.0)
    y = jnp.abs(x) / safe[..., None]
    # nearest grid level in linear distance: floor-log bin, then pick the
    # closer of its two endpoints (ties go up, matching jnp.round's bias
    # direction for the int8 path)
    k = jnp.clip(jnp.floor(jnp.log2(jnp.maximum(y, 2.0 ** -FP4_LEVELS))),
                 -float(FP4_LEVELS - 1), 0.0)
    low = jnp.exp2(k)
    high = jnp.minimum(2.0 * low, 1.0)
    m = k + 7.0 + ((y - low) >= (high - y)).astype(jnp.float32)
    # underflow: below half the smallest level, round to exactly zero
    m = jnp.where(y < 2.0 ** -FP4_LEVELS, 0.0, jnp.clip(m, 1.0, 7.0))
    return m + 8.0 * ((x < 0) & (m > 0)).astype(jnp.float32)


def fp4_decode_unit(codes: jax.Array) -> jax.Array:
    """Unpacked integer codes 0..15 -> f32 grid values in [-1, 1]."""
    m = (codes & 7).astype(jnp.float32)
    sgn = 1.0 - 2.0 * ((codes >> 3) & 1).astype(jnp.float32)
    return jnp.where(m > 0, jnp.exp2(m - 7.0), 0.0) * sgn


def fp4_pack(codes: jax.Array) -> jax.Array:
    """Pack (..., head_dim) uint8 codes two per byte; even index = low
    nibble."""
    lo = codes[..., 0::2].astype(jnp.uint8)
    hi = codes[..., 1::2].astype(jnp.uint8)
    return lo | (hi << 4)


def fp4_unpack(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`fp4_pack`: (..., D/2) uint8 -> (..., D) int32."""
    lo = (packed & 0xF).astype(jnp.int32)
    hi = ((packed >> 4) & 0xF).astype(jnp.int32)
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)


# --------------------------------------------------------------------------- #
# ref impls of the dispatched ops
# --------------------------------------------------------------------------- #
def kv_quant(fmt: str, x: jax.Array):
    """Quantize K/V rows ``(..., head_dim)`` -> ``(codes, scales)``.

    ``scales`` is ``(...,)`` bfloat16, one per row; ``fmt == "none"``
    returns ``(x, None)`` unchanged.  Deterministic (no RNG key): cache
    writes must read back identically wherever and whenever they happen.
    """
    if fmt == "none":
        return x, None
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    if fmt == "int8":
        scale = int8_row_scale(amax)
        codes = int8_encode(xf, scale).astype(jnp.int8)
    elif fmt == "luq_fp4":
        scale = fp4_row_scale(amax)
        codes = fp4_pack(fp4_encode(xf, scale).astype(jnp.uint8))
    else:
        raise ValueError(f"unknown kv cache format {fmt!r}")
    return codes, scale.astype(SCALE_DTYPE)


def kv_dequant(fmt: str, codes: jax.Array, scales) -> jax.Array:
    """Decode stored rows back to f32 (identity for ``"none"``).

    A zero scale decodes the whole row to exactly zero regardless of the
    stored codes — which is why the engine zeroes a retired slot's scale
    rows instead of its (much larger) code rows.
    """
    if fmt == "none":
        return codes
    s = scales.astype(jnp.float32)[..., None]
    if fmt == "int8":
        return codes.astype(jnp.float32) * s
    if fmt == "luq_fp4":
        return fp4_decode_unit(fp4_unpack(codes)) * s
    raise ValueError(f"unknown kv cache format {fmt!r}")


def ref_decode_attn(fmt: str, q, k_codes, v_codes, k_scale, v_scale, pos, *,
                    n_kv: int, scale: float):
    """One-token GQA attention over the (quantized) cache — the reference.

    ``q``: (B, H, hd); ``k_codes``/``v_codes``: (B, KV, S, code_dim) stored
    rows; ``k_scale``/``v_scale``: (B, KV, S) bf16 (None for ``"none"``);
    ``pos``: scalar or (B,) per-row positions; ``scale``: the attention
    softmax scale (1/sqrt(head_dim)).  Returns (B, H, hd).

    For ``fmt == "none"`` this is operation-for-operation the historical
    ``models.transformer.decode_attend`` math (bit-identical); quantized
    formats dequantize the cache and run the same contraction.
    """
    B, hp, hd = q.shape
    g = hp // n_kv
    qg = q.reshape(B, n_kv, g, hd)
    k = kv_dequant(fmt, k_codes, k_scale)
    v = kv_dequant(fmt, v_codes, v_scale)
    scores = jnp.einsum("bkgd,bksd->bkgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))
    valid = (jnp.arange(k.shape[2])[None, None, None, :]
             <= pos_b[:, None, None, None])
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bkgs,bksd->bkgd", probs.astype(v.dtype), v)
    return ctx.reshape(B, hp, hd)
