from repro.runtime.elastic import MeshPlan, degrade_sequence, plan_remesh
from repro.runtime.heartbeat import FailureDetector, Heartbeat
from repro.runtime.straggler import StragglerDetector

__all__ = ["MeshPlan", "degrade_sequence", "plan_remesh",
           "FailureDetector", "Heartbeat", "StragglerDetector"]
