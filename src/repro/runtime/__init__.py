from repro.runtime.elastic import MeshPlan, degrade_sequence, plan_remesh
from repro.runtime.faults import (DEFAULT_FREEZE_READS, FAULT_KINDS,
                                  FaultEvent, FaultInjected, FaultPlan)
from repro.runtime.heartbeat import FailureDetector, Heartbeat
from repro.runtime.preemption import Preempted, PreemptionHandler
from repro.runtime.straggler import StragglerDetector
from repro.runtime.supervisor import (DegradeToOneshot, ServeSupervisor,
                                      drain_with_oneshot, run_supervised)

__all__ = ["MeshPlan", "degrade_sequence", "plan_remesh",
           "FailureDetector", "Heartbeat", "StragglerDetector",
           "DEFAULT_FREEZE_READS", "FAULT_KINDS", "FaultEvent",
           "FaultInjected", "FaultPlan",
           "Preempted", "PreemptionHandler",
           "DegradeToOneshot", "ServeSupervisor", "drain_with_oneshot",
           "run_supervised"]
