"""Elastic re-meshing after failures.

Given the surviving host/chip count, pick the largest expressible mesh
(keeping the model axis intact when possible — TP degree is baked into
weight-shard divisibility, so we prefer shrinking the data/pod axes), and
re-derive the DP accounting rate: privacy accounting is per-step (sigma, q)
tuples, so a batch-size change on re-mesh is accounted exactly by updating
the sample rate of subsequent steps.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass
class MeshPlan:
    shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    global_batch: int
    sample_rate: float


def plan_remesh(n_chips: int, model_parallel: int,
                per_replica_batch: int, dataset_size: int,
                pods: int = 1) -> Optional[MeshPlan]:
    """Largest (data, model) mesh with the given TP degree that fits
    ``n_chips``; None if even one replica no longer fits."""
    if n_chips < model_parallel:
        return None
    data = n_chips // model_parallel
    global_batch = data * per_replica_batch
    return MeshPlan(
        shape=(data, model_parallel),
        axis_names=("data", "model"),
        global_batch=global_batch,
        sample_rate=min(1.0, global_batch / dataset_size),
    )


def degrade_sequence(start_chips: int, model_parallel: int,
                     per_replica_batch: int, dataset_size: int,
                     failures: List[int]) -> List[MeshPlan]:
    """Simulate successive failures; returns the mesh plan after each."""
    plans = []
    chips = start_chips
    for lost in failures:
        chips -= lost
        plan = plan_remesh(chips, model_parallel, per_replica_batch,
                           dataset_size)
        if plan is None:
            break
        plans.append(plan)
    return plans
