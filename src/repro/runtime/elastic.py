"""Elastic re-meshing after failures.

Given the surviving host/chip count, pick the largest expressible mesh
(keeping the model axis intact when possible — TP degree is baked into
weight-shard divisibility, so we prefer shrinking the data/pod axes), and
re-derive the DP accounting rate: privacy accounting is per-step (sigma, q)
tuples, so a batch-size change on re-mesh is accounted exactly by updating
the sample rate of subsequent steps.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass
class MeshPlan:
    shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    global_batch: int
    sample_rate: float


def plan_remesh(n_chips: int, model_parallel: int,
                per_replica_batch: int, dataset_size: int,
                pods: int = 1) -> Optional[MeshPlan]:
    """Largest mesh with the given TP degree that fits ``n_chips``.

    ``n_chips`` is the *total* surviving chip count across ``pods``; with
    ``pods > 1`` the mesh gains a leading pod axis and the data degree is
    what fits per pod (every pod must host the same sub-mesh), so the
    shape is ``(pods, data, model)``.  Returns None if even one replica no
    longer fits.
    """
    if pods < 1:
        raise ValueError(f"pods must be >= 1, got {pods}")
    data = n_chips // (model_parallel * pods)
    if data < 1:
        return None
    global_batch = pods * data * per_replica_batch
    if pods > 1:
        shape: Tuple[int, ...] = (pods, data, model_parallel)
        axis_names: Tuple[str, ...] = ("pod", "data", "model")
    else:
        shape = (data, model_parallel)
        axis_names = ("data", "model")
    return MeshPlan(
        shape=shape,
        axis_names=axis_names,
        global_batch=global_batch,
        sample_rate=min(1.0, global_batch / dataset_size),
    )


def degrade_sequence(start_chips: int, model_parallel: int,
                     per_replica_batch: int, dataset_size: int,
                     failures: List[int]) -> List[MeshPlan]:
    """Simulate successive failures; returns the mesh plan after each."""
    plans = []
    chips = start_chips
    for lost in failures:
        chips -= lost
        plan = plan_remesh(chips, model_parallel, per_replica_batch,
                           dataset_size)
        if plan is None:
            break
        plans.append(plan)
    return plans
