"""Deterministic seeded fault injection for the serve and train loops.

A ``FaultPlan`` is an explicit, seed-derived schedule of fault events —
*which* fault, *when* (a dispatch/tick/step counter, not wall time), and
*where* (a slot / replica target).  The serving engine, the serve
supervisor, and the trainer each poll the plan at explicit hook points
(``take``), so every failure-recovery path in this repo is reproducible:
the same seed produces the same faults at the same counters on every run,
in tests and in CI's chaos leg alike.

Fault kinds and the counter domain each is polled against:

=================  =========================  ==============================
kind               counter domain             injected effect
=================  =========================  ==============================
``prefill_fail``   engine prefill attempts    admission prefill dispatch
                                              raises; request re-queued with
                                              backoff
``decode_fail``    engine decode ticks        the fused decode tick raises;
                                              every active request loses its
                                              slot and is re-queued for
                                              deterministic replay
``slot_corrupt``   engine decode ticks        a slot's cache rows (codes and
                                              scales) are overwritten with
                                              garbage; modelled as *detected*
                                              poison (ECC-style), so the
                                              occupant is replayed
``clock_freeze``   engine decode ticks        the engine's clock returns a
                                              frozen value for ``duration``
                                              reads, then thaws
``replica_death``  supervisor ticks           a virtual replica stops
                                              heartbeating; the failure
                                              detector evicts it and the
                                              supervisor re-plans the mesh
``replica_slow``   supervisor ticks           a replica's reported tick time
                                              is multiplied by ``factor`` so
                                              the straggler detector flags it
``preempt``        trainer step index         the trainer checkpoints
                                              mid-epoch and stops
=================  =========================  ==============================

Counters are per-domain, so one plan can drive serve and train hooks
simultaneously without collisions.  Every fired event is appended to
``FaultPlan.log`` (JSON-serializable) — CI uploads it as the chaos
artifact.

Determinism is the point: serving sampling keys are derived from
``(request_id, position)`` and KV-cache quantization is deterministic, so
replaying a failed request reconstructs its tokens bit-for-bit
(docs/SERVING.md "Failure model & recovery"); DP accounting is per-step
``(sigma, q)`` tuples, so recovery never perturbs the privacy guarantee.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional, Sequence

import numpy as np

FAULT_KINDS = ("prefill_fail", "decode_fail", "slot_corrupt", "clock_freeze",
               "replica_death", "replica_slow", "preempt")

# Default number of clock reads a clock_freeze holds time still for.  Kept
# well under the engine's frozen-clock stall guard (1000 idle iterations)
# so an injected freeze can never be mistaken for a hung injected clock.
DEFAULT_FREEZE_READS = 8


class FaultInjected(RuntimeError):
    """Raised by an injected dispatch failure (prefill/decode)."""

    def __init__(self, event: "FaultEvent"):
        """Wrap the fault event that fired."""
        super().__init__(f"injected fault: {event}")
        self.event = event


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: what, when (a counter value), and where."""

    kind: str
    at: int                 # counter value in the kind's domain (see module doc)
    target: int = -1        # slot / replica index; -1 = unspecified
    duration: int = 0       # clock_freeze: reads held frozen (0 = default)
    factor: float = 4.0     # replica_slow: tick-time multiplier

    def __post_init__(self):
        """Validate the kind and schedule point."""
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"{FAULT_KINDS}")
        if self.at < 0:
            raise ValueError(f"fault scheduled at negative counter {self.at}")


class FaultPlan:
    """A consumable, seed-reproducible schedule of :class:`FaultEvent`.

    ``take(kind, at)`` returns (and consumes) every pending event of
    ``kind`` whose schedule point is ``<= at`` — the ``<=`` makes plans
    robust to counters that skip values (e.g. a tick that also consumed a
    failure).  Consumed events are appended to ``log`` with the counter
    value they actually fired at.
    """

    def __init__(self, events: Sequence[FaultEvent] = (), *, seed: int = 0):
        """Hold ``events`` (kept sorted by schedule point) for consumption."""
        self.seed = seed
        self._pending: List[FaultEvent] = sorted(events, key=lambda e: e.at)
        self.log: List[dict] = []

    # ------------------------------------------------------------------ #
    @classmethod
    def generate(cls, seed: int, *, kinds: Sequence[str] = FAULT_KINDS,
                 horizon: int, n_faults: Optional[int] = None,
                 n_slots: int = 1, n_replicas: int = 1,
                 freeze_reads: int = DEFAULT_FREEZE_READS,
                 slow_factor: float = 4.0) -> "FaultPlan":
        """Derive a plan purely from ``seed``.

        ``n_faults`` events (default: one per kind, round-robin over
        ``kinds``) are scheduled uniformly over ``[1, horizon)`` with
        uniformly-drawn slot/replica targets.  Same arguments + same seed
        => the identical plan, which is what makes every chaos test and
        the CI chaos leg reproducible.
        """
        if horizon < 2:
            raise ValueError(f"horizon must be >= 2, got {horizon}")
        for k in kinds:
            if k not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        rng = np.random.default_rng(seed)
        n = n_faults if n_faults is not None else len(kinds)
        events = []
        for i in range(n):
            kind = kinds[i % len(kinds)]
            at = int(rng.integers(1, horizon))
            target = int(rng.integers(0, max(n_slots, 1)))
            if kind in ("replica_death", "replica_slow"):
                target = int(rng.integers(0, max(n_replicas, 1)))
            events.append(FaultEvent(
                kind=kind, at=at, target=target,
                duration=freeze_reads if kind == "clock_freeze" else 0,
                factor=slow_factor))
        return cls(events, seed=seed)

    # ------------------------------------------------------------------ #
    @property
    def pending(self) -> List[FaultEvent]:
        """Events not yet consumed, in schedule order."""
        return list(self._pending)

    def take(self, kind: str, at: int) -> List[FaultEvent]:
        """Consume every pending ``kind`` event scheduled at ``<= at``."""
        due = [e for e in self._pending if e.kind == kind and e.at <= at]
        if due:
            self._pending = [e for e in self._pending if e not in due]
            for e in due:
                self.log.append({**dataclasses.asdict(e), "fired_at": at})
        return due

    def has_pending(self, kind: Optional[str] = None) -> bool:
        """Whether any (or any ``kind``) events remain unconsumed."""
        return any(kind is None or e.kind == kind for e in self._pending)

    # ------------------------------------------------------------------ #
    def log_json(self, extra: Optional[dict] = None) -> str:
        """The fired-event log (plus ``extra`` context) as a JSON string."""
        return json.dumps({"seed": self.seed, "fired": self.log,
                           "pending": [dataclasses.asdict(e)
                                       for e in self._pending],
                           **(extra or {})}, indent=2)
