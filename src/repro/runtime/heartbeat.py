"""Heartbeat-file failure detection.

Each host process periodically touches ``<dir>/host_<id>.hb`` with its
current step; the (distributed, leaderless) detector marks hosts whose
heartbeat is older than ``deadline_s`` as dead.  On a real cluster the same
files live on shared storage (GCS/NFS); here they are local files so the
logic is unit-testable.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List


class Heartbeat:
    def __init__(self, directory: str, host_id: int):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.host_id = host_id
        self.path = self.dir / f"host_{host_id}.hb"

    def beat(self, step: int, now: float = None) -> None:
        payload = {"step": step, "t": time.time() if now is None else now}
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps(payload))
        tmp.replace(self.path)


class FailureDetector:
    def __init__(self, directory: str, deadline_s: float = 60.0):
        self.dir = Path(directory)
        self.deadline_s = deadline_s

    def snapshot(self, now: float = None) -> Dict[int, dict]:
        now = time.time() if now is None else now
        out = {}
        for p in self.dir.glob("host_*.hb"):
            try:
                data = json.loads(p.read_text())
                hid = int(p.stem.split("_", 1)[1])
            except (json.JSONDecodeError, OSError, ValueError, IndexError):
                # unreadable payloads and malformed filenames (non-numeric
                # host ids, stray files matching the glob) are skipped, not
                # fatal — a garbage file on shared storage must never take
                # down the detector
                continue
            data["age"] = now - data["t"]
            data["alive"] = data["age"] <= self.deadline_s
            out[hid] = data
        return out

    def dead_hosts(self, now: float = None) -> List[int]:
        return sorted(h for h, d in self.snapshot(now).items()
                      if not d["alive"])

    def alive_hosts(self, now: float = None) -> List[int]:
        return sorted(h for h, d in self.snapshot(now).items()
                      if d["alive"])
