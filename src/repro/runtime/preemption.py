"""Preemption handling for the training loop.

A ``PreemptionHandler`` turns two interrupt sources into one polled flag:

* **signals** — SIGTERM (the notice a scheduler gives an evicted job);
* **injected faults** — ``FaultPlan`` "preempt" events polled against the
  trainer's global step counter, so preemption tests are seed-exact.

``Trainer`` polls ``should_preempt(step)`` at its step boundaries; when it
fires, the trainer writes a *mid-epoch* checkpoint (params, opt state,
accountant history, DPQuant scheduler EMA, sampler + probe RNG stream
positions, epoch step index) and raises :class:`Preempted`.  The resume
path restores all of that, which is what makes a preempted-and-resumed
run bit-identical to an uninterrupted one (tests/test_preemption.py).
"""
from __future__ import annotations

import signal
from typing import Optional

from repro.runtime.faults import FaultPlan


class Preempted(RuntimeError):
    """Raised by the trainer after a preemption checkpoint was written."""

    def __init__(self, step: int, message: str = ""):
        """Record the global step the run was preempted at."""
        super().__init__(message or f"preempted at step {step}")
        self.step = step


class PreemptionHandler:
    """One polled preemption flag fed by signals and/or injected faults."""

    def __init__(self, faults: Optional[FaultPlan] = None,
                 handle_signals: bool = False):
        """Optionally consume ``faults`` and/or install a SIGTERM handler."""
        self.faults = faults
        self._requested = False
        self._prev_handlers = {}
        if handle_signals:
            self.install()

    def install(self, signals=(signal.SIGTERM,)) -> None:
        """Route ``signals`` to the preemption flag (remembers old handlers).

        Only callable from the main thread (a Python ``signal`` limitation);
        workers driving the trainer from another thread use ``request()``.
        """
        for s in signals:
            self._prev_handlers[s] = signal.signal(s, self._on_signal)

    def uninstall(self) -> None:
        """Restore the signal handlers ``install`` replaced."""
        for s, h in self._prev_handlers.items():
            signal.signal(s, h)
        self._prev_handlers = {}

    def _on_signal(self, signum, frame) -> None:
        self._requested = True

    def request(self) -> None:
        """Request preemption programmatically (tests, external watchers)."""
        self._requested = True

    @property
    def requested(self) -> bool:
        """Whether preemption is pending (without consuming fault events)."""
        return self._requested

    def should_preempt(self, step: int) -> bool:
        """Poll at a step boundary: injected "preempt" events at ``<= step``
        (trainer global-step domain) latch the flag, as do signals."""
        if self.faults is not None and self.faults.take("preempt", step):
            self._requested = True
        return self._requested

    def clear(self) -> None:
        """Drop a latched request (after the checkpoint was written)."""
        self._requested = False
