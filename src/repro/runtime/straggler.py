"""Straggler detection: per-host step-time EWMA + deviation policy.

A host is flagged when its step-time EWMA exceeds ``mu + k*sigma`` of the
fleet for ``patience`` consecutive windows; flagged hosts are reported for
eviction (the elastic planner then re-meshes without them).  DP noise is
key-derived, so recomputing a flagged host's shard elsewhere is
bit-identical — eviction never perturbs the privacy accounting.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List


@dataclasses.dataclass
class HostStats:
    ewma: float = 0.0
    n: int = 0
    strikes: int = 0


class StragglerDetector:
    def __init__(self, alpha: float = 0.2, k_sigma: float = 3.0,
                 patience: int = 3):
        self.alpha = alpha
        self.k_sigma = k_sigma
        self.patience = patience
        self.hosts: Dict[int, HostStats] = {}

    def record(self, host_id: int, step_time_s: float) -> None:
        st = self.hosts.setdefault(host_id, HostStats())
        st.ewma = (step_time_s if st.n == 0
                   else (1 - self.alpha) * st.ewma + self.alpha * step_time_s)
        st.n += 1

    def _fleet_stats(self):
        vals = [s.ewma for s in self.hosts.values() if s.n > 0]
        if len(vals) < 2:
            return None, None
        mu = sum(vals) / len(vals)
        var = sum((v - mu) ** 2 for v in vals) / (len(vals) - 1)
        return mu, math.sqrt(var)

    def update_strikes(self) -> None:
        mu, sigma = self._fleet_stats()
        if mu is None:
            return
        thresh = mu + self.k_sigma * max(sigma, 1e-9) + 1e-12
        for st in self.hosts.values():
            if st.ewma > thresh:
                st.strikes += 1
            else:
                st.strikes = 0

    def stragglers(self) -> List[int]:
        return sorted(h for h, s in self.hosts.items()
                      if s.strikes >= self.patience)
