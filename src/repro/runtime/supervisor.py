"""Serve supervisor: SLO instrumentation and degraded-mode handling.

Wires the previously-dormant runtime seeds (``Heartbeat`` /
``FailureDetector``, ``StragglerDetector``, ``plan_remesh``) into the
continuous engine's tick loop via its ``on_tick`` hook.  The supervisor
models the serving fleet as ``n_replicas`` virtual replicas sharing the
engine's clock:

* every tick, each live replica beats its heartbeat file and records the
  tick wall time into the straggler EWMA (a ``replica_slow`` fault
  multiplies one replica's reported time by ``factor``);
* a ``replica_death`` fault stops a replica's heartbeats, so the
  ``FailureDetector`` declares it dead once its last beat ages past the
  deadline on the same clock;
* dead or straggling replicas trigger the degraded-mode ladder
  (docs/SERVING.md "Failure model & recovery"):

  1. **re-plan** — ``plan_remesh`` over the surviving chips, and the
     engine's admission cap shrinks proportionally
     (``set_slot_cap``) so the smaller fleet is not oversubscribed;
  2. **oneshot fallback** — after ``slot_fault_threshold`` slot-pool
     faults the slot cache is presumed unreliable;
     :class:`DegradeToOneshot` aborts the tick loop and
     ``drain_with_oneshot`` finishes every unfinished request on the
     B=1 lockstep driver, sampling with the *engine's*
     ``(request_id, position)`` key schedule so tokens stay
     bit-identical to a fault-free continuous run;
  3. **shed** — with no capacity at all, admission control rejects new
     work at submit (``ServeConfig.max_queue``).

Every degraded event is appended to ``ServeSupervisor.events`` and
counted in ``ServeMetrics.degraded_events``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.elastic import plan_remesh
from repro.runtime.faults import FaultPlan
from repro.runtime.heartbeat import FailureDetector, Heartbeat
from repro.runtime.straggler import StragglerDetector


class DegradeToOneshot(RuntimeError):
    """Slot pool faulted too often; abort the tick loop for the fallback."""


class ServeSupervisor:
    """Heartbeat/straggler supervision of a ``ContinuousEngine`` run.

    Construction attaches the supervisor to ``engine.on_tick``.  Drive the
    engine through :func:`run_supervised` (or call ``engine.run`` and
    catch :class:`DegradeToOneshot` yourself).
    """

    def __init__(self, engine, *, n_replicas: int = 2,
                 hb_dir: Optional[str] = None,
                 hb_deadline_s: float = 2.0,
                 faults: Optional[FaultPlan] = None,
                 chips_per_replica: int = 1,
                 model_parallel: int = 1,
                 per_replica_batch: int = 1,
                 dataset_size: int = 1_000_000,
                 slot_fault_threshold: int = 3,
                 straggler_patience: int = 3):
        """Attach to ``engine`` and model an ``n_replicas`` virtual fleet.

        ``hb_dir`` enables file-based failure detection (tests use a
        tmpdir); without it a killed replica is declared dead on the next
        tick directly.  ``faults`` defaults to the engine's plan so one
        seeded plan drives both tick-level and replica-level events.
        """
        if n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        self.engine = engine
        self.n_replicas = n_replicas
        self.faults = faults if faults is not None else engine.faults
        self.chips_per_replica = chips_per_replica
        self.model_parallel = model_parallel
        self.per_replica_batch = per_replica_batch
        self.dataset_size = dataset_size
        self.slot_fault_threshold = slot_fault_threshold
        self.straggler = StragglerDetector(patience=straggler_patience)
        self.detector = (FailureDetector(hb_dir, deadline_s=hb_deadline_s)
                         if hb_dir else None)
        self.heartbeats: Dict[int, Heartbeat] = (
            {r: Heartbeat(hb_dir, r) for r in range(n_replicas)}
            if hb_dir else {})
        self._killed: Set[int] = set()      # stopped beating (fault fired)
        self._slow: Dict[int, float] = {}   # replica -> tick-time factor
        self.dead: Set[int] = set()         # declared dead / evicted
        self.plans: List = []               # MeshPlan after each re-plan
        self.events: List[dict] = []        # degraded-event log
        self._tick = 0
        self._oneshot_raised = False
        engine.on_tick = self.on_tick

    # ------------------------------------------------------------------ #
    def live_replicas(self) -> List[int]:
        """Replicas not yet declared dead, in id order."""
        return [r for r in range(self.n_replicas) if r not in self.dead]

    def on_tick(self, tick: int, dt: float, now: float) -> None:
        """Per-tick supervision: beats, EWMA, detection, degraded ladder."""
        t = self._tick
        self._tick += 1
        if self.faults is not None:
            for ev in self.faults.take("replica_death", t):
                self.engine.metrics.faults_injected += 1
                self._killed.add(ev.target % self.n_replicas)
            for ev in self.faults.take("replica_slow", t):
                self.engine.metrics.faults_injected += 1
                self._slow[ev.target % self.n_replicas] = ev.factor
        for r in self.live_replicas():
            if r in self._killed:
                continue                    # dead replicas stop beating
            if self.heartbeats:
                self.heartbeats[r].beat(step=tick, now=now)
            self.straggler.record(r, dt * self._slow.get(r, 1.0))
        self.straggler.update_strikes()
        newly_dead = set()
        if self.detector is not None:
            newly_dead |= {r for r in self.detector.dead_hosts(now=now)
                           if r not in self.dead}
        else:
            newly_dead |= self._killed - self.dead
        newly_dead |= {r for r in self.straggler.stragglers()
                       if r not in self.dead}
        if newly_dead:
            self.dead |= newly_dead
            self._replan(now, sorted(newly_dead))
        if (self.engine.metrics.slot_faults >= self.slot_fault_threshold
                and not self._oneshot_raised):
            self._oneshot_raised = True
            self.engine.metrics.degraded_events += 1
            self.events.append({"t": now, "kind": "oneshot_fallback",
                                "slot_faults":
                                    self.engine.metrics.slot_faults})
            raise DegradeToOneshot(
                f"{self.engine.metrics.slot_faults} slot-pool faults "
                f">= threshold {self.slot_fault_threshold}")

    def _replan(self, now: float, lost: List[int]) -> None:
        """Degraded-mode re-plan after replica loss / straggler eviction."""
        n_live = len(self.live_replicas())
        plan = plan_remesh(n_live * self.chips_per_replica,
                           self.model_parallel, self.per_replica_batch,
                           self.dataset_size)
        self.plans.append(plan)
        # shrink admissions proportionally to surviving capacity; the
        # engine clamps to >= 1 (it is the one real executor here)
        cap = max(1, (self.engine.serve.max_slots * max(n_live, 1))
                  // self.n_replicas)
        self.engine.set_slot_cap(cap)
        self.engine.metrics.degraded_events += 1
        self.events.append({
            "t": now, "kind": "replan", "lost": lost,
            "live": self.live_replicas(), "slot_cap": self.engine.slot_cap,
            "plan": dataclasses.asdict(plan) if plan is not None else None})


# ---------------------------------------------------------------------- #
# oneshot fallback
# ---------------------------------------------------------------------- #
def drain_with_oneshot(engine, now: float = 0.0):
    """Finish every unfinished engine request on the B=1 lockstep driver.

    Uses the *engine's* sampling-key schedule (``sampling_key(base_key,
    request_id, position)``; position = original prompt length + token
    index) rather than the legacy shared oneshot key, and mirrors the
    engine's retirement conditions exactly (budget / EOS / cache full), so
    drained tokens are bit-identical to a fault-free continuous run.
    Returns the engine's full results dict.
    """
    from repro.launch.steps import build_serve_setup
    from repro.serve.engine import sampling_key

    pending = engine.takeover_unfinished()
    if not pending:
        return dict(engine.results)
    setup = build_serve_setup(engine.model, None, engine.mesh, 1,
                              engine.serve.max_seq,
                              kv_fmt=engine.serve.kv_fmt)
    prefill = jax.jit(setup.prefill_fn)
    decode = jax.jit(setup.decode_fn)
    temperature = engine.serve.temperature
    max_seq = engine.serve.max_seq

    def pick(logits_row, rid, pos):
        if temperature > 0:
            k = sampling_key(engine._base_key, rid, pos)
            return int(jax.random.categorical(k, logits_row / temperature))
        return int(jnp.argmax(logits_row))

    for req, prefix in pending:
        exp = req.expiry()
        if exp is not None and exp <= now:
            engine.finalize_external(req, prefix, now, status="timed_out")
            continue
        toks = list(prefix)
        seq = np.concatenate(
            [req.prompt, np.asarray(toks, np.int32)]).astype(np.int32)
        logits, cache = prefill(engine.params,
                                {"tokens": jnp.asarray(seq[None, :])})
        pos = int(seq.size)             # position of the next sample
        remaining = req.max_new_tokens - len(toks)
        rid = req.request_id
        while remaining > 0:
            tok = pick(logits[0], rid, pos)
            toks.append(tok)
            remaining -= 1
            # same retirement conditions as ContinuousEngine._record_token:
            # budget, EOS, or the recorded token's cache index (== pos)
            # falling outside the slot
            if (remaining <= 0
                    or (req.eos_id is not None and tok == req.eos_id)
                    or pos >= max_seq):
                break
            logits, cache = decode(engine.params, cache,
                                   jnp.asarray([tok], jnp.int32))
            pos += 1
        engine.finalize_external(req, toks, now, status="ok")
    return dict(engine.results)


def run_supervised(engine, clock=None):
    """``engine.run`` with the supervisor's oneshot-fallback rung applied."""
    try:
        return engine.run(clock=clock)
    except DegradeToOneshot:
        # the slot pool is presumed unreliable: drain what's left on the
        # lockstep driver (token-identical; see drain_with_oneshot)
        now = engine.metrics.run_wall
        return drain_with_oneshot(engine, now=now)
