"""Serving subsystem: continuous batching over a slot-pool KV cache.

Public surface:

* ``ContinuousEngine`` / ``Request`` / ``RequestResult`` — the scheduler
  (``repro.serve.engine``),
* ``SlotPool`` — slot bookkeeping (``repro.serve.slots``),
* ``ServeMetrics`` — throughput/latency accounting
  (``repro.serve.metrics``),
* ``oneshot_generate`` / ``build_oneshot_fns`` — the lockstep reference
  driver (``repro.serve.oneshot``).

See docs/SERVING.md for the slot lifecycle, admission policy, cache
layout, and the sampling-key schedule.
"""
from repro.serve.engine import (ContinuousEngine, Request, RequestResult,
                                SAMPLE_FOLD, sampling_key)
from repro.serve.metrics import RequestTiming, ServeMetrics
from repro.serve.oneshot import build_oneshot_fns, oneshot_generate
from repro.serve.slots import SlotPool, SlotState, init_slot_cache

__all__ = [
    "ContinuousEngine", "Request", "RequestResult", "SAMPLE_FOLD",
    "sampling_key", "RequestTiming", "ServeMetrics", "build_oneshot_fns",
    "oneshot_generate", "SlotPool", "SlotState", "init_slot_cache",
]
