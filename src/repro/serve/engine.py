"""Continuous-batching serving engine over a slot-pool KV cache.

One ``ContinuousEngine`` owns a fixed ``max_slots x max_seq`` KV cache and
runs the scheduler loop::

    while queue or active slots:
        admit queued requests into free slots   (batched B=1 prefill each)
        one fused masked decode tick            (all active slots at once)
        sample one token per slot               (per-slot, per-position keys)
        retire finished slots                   (budget / EOS / cache full)

Requests of different prompt and generation lengths therefore share the
device batch: a short request retires and its slot is refilled from the
queue while long requests keep decoding — the decode batch stays full
instead of lockstepping to the longest sequence (the oneshot driver's
failure mode, kept in ``repro.serve.oneshot`` as the reference).

Quantized decode works unchanged: ``decode_slots`` routes each slot's
logits row through the quantizer-backend dispatcher
(``repro.quant.backend``) with the position-derived key
``fold_in(PRNGKey(17), 2*pos + 1)``, so ``--quant-fmt luq_fp4 --backend
pallas`` serves under continuous batching and a single greedy request
reproduces the oneshot tokens bit-for-bit.

Sampling key schedule (docs/SERVING.md): every sampled token uses
``fold_in(fold_in(fold_in(PRNGKey(seed), SAMPLE_FOLD), request_id),
position)`` — domain-separated from the quantizer streams by SAMPLE_FOLD,
and unique per (request, position) so concurrent slots never share a key.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ServeConfig
from repro.launch.mesh import make_host_mesh
from repro.parallel import partitioner as pt
from repro.parallel.axes import partitioning_context
from repro.serve.metrics import ServeMetrics
from repro.serve.slots import SlotPool, init_slot_cache

# Domain-separation fold for sampling keys.  Chosen once and fixed: the
# quantizer streams fold small per-layer seeds (fake_quant) and the logits
# head folds 2*pos(+1) off PRNGKey(17), so a dedicated large fold off the
# *user* seed keeps the sampling stream disjoint from both.
SAMPLE_FOLD = 0x53A7


def sampling_key(base_key: jax.Array, request_id, position) -> jax.Array:
    """Per-request, per-position sampling key (see module docstring).

    ``request_id`` and ``position`` may be python ints or traced int32
    scalars; distinct (request_id, position) pairs give distinct keys, so
    two slots decoding the same position draw independent bits.
    """
    k = jax.random.fold_in(base_key, SAMPLE_FOLD)
    k = jax.random.fold_in(k, request_id)
    return jax.random.fold_in(k, position)


@dataclasses.dataclass
class Request:
    """A queued generation request."""

    request_id: int
    prompt: np.ndarray              # (S,) int32 token ids
    max_new_tokens: int
    arrival_time: float = 0.0       # seconds relative to run() start
    eos_id: Optional[int] = None


@dataclasses.dataclass
class RequestResult:
    """Completed request: generated ids plus its timing record."""

    request_id: int
    prompt: np.ndarray
    tokens: np.ndarray              # (n_generated,) int32
    timing: object                  # metrics.RequestTiming


class ContinuousEngine:
    """Slot-pool scheduler running fused masked decode over active slots.

    Parameters
    ----------
    model:
        A ``repro.models.registry.Model`` with the slot hooks
        (``decode_slots`` / ``slot_cache_spec``); currently the dense
        transformer family implements them.
    params:
        The model's parameter pytree.
    serve:
        ``repro.config.ServeConfig`` — slot count, cache length, sampling
        temperature and seed.
    mesh:
        Optional ``jax.sharding.Mesh``; defaults to the host mesh.  The
        prefill/decode functions run under the same partitioning context
        the oneshot driver uses, so sharding annotations resolve
        identically.
    """

    def __init__(self, model, params, serve: ServeConfig, mesh=None):
        """Allocate the slot cache and jit the engine's device functions."""
        if model.decode_slots is None or model.slot_cache_spec is None:
            raise ValueError(
                f"model family {model.config.family!r} does not support "
                "continuous batching (no decode_slots/slot_cache_spec)")
        extra = set(model.batch_spec(1, 2)) - {"tokens"}
        if extra:
            # fail at construction, not deep inside prefill at admission:
            # _admit builds {"tokens": prompt} only, so families whose
            # batch_spec needs more inputs (encdec enc_embeds, vlm vision
            # embeds) need a prompt-to-batch hook before they can ride the
            # slot engine
            raise ValueError(
                f"continuous batching supports token-only prompts; family "
                f"{model.config.family!r} also requires {sorted(extra)}")
        self.model = model
        self.params = params
        self.serve = serve
        self.mesh = mesh if mesh is not None else make_host_mesh()
        rules = pt.merge_rules(pt.DEFAULT_RULES,
                               model.config.sharding_overrides)
        self._resolver = pt.activation_resolver(self.mesh, rules)
        self._base_key = jax.random.PRNGKey(serve.seed)
        self._jit_fns()
        self.reset()

    # ------------------------------------------------------------------ #
    # device functions
    # ------------------------------------------------------------------ #
    def _jit_fns(self):
        """Build the jitted prefill / cache-write / decode / sample fns."""
        model, resolver = self.model, self._resolver
        temperature, base_key = self.serve.temperature, self._base_key

        def prefill_fn(params, batch):
            with partitioning_context(resolver):
                return model.prefill(params, batch)

        def step_fn(params, cache, tokens, active, rids):
            # fused decode + sample: one dispatch and one (K,) device->host
            # transfer per tick (the (K, V) logits never leave the device)
            with partitioning_context(resolver):
                logits, cache = model.decode_slots(params, cache, tokens,
                                                   active)
            pos = cache["pos"]
            if temperature > 0:
                keys = jax.vmap(
                    lambda r, p: sampling_key(base_key, r, p))(rids, pos)
                toks = jax.vmap(lambda k, row: jax.random.categorical(
                    k, row / temperature))(keys, logits)
            else:
                toks = jnp.argmax(logits, -1)
            return toks.astype(jnp.int32), cache

        def write_fn(cache, kc, vc, slot, prompt_len):
            k = jax.lax.dynamic_update_slice(
                cache["k"], kc.astype(cache["k"].dtype), (0, slot, 0, 0, 0))
            v = jax.lax.dynamic_update_slice(
                cache["v"], vc.astype(cache["v"].dtype), (0, slot, 0, 0, 0))
            pos = cache["pos"].at[slot].set(prompt_len)
            return {"k": k, "v": v, "pos": pos}

        # prefill retraces per distinct prompt length (static shapes);
        # step/write compile once for the slot geometry
        self._prefill = jax.jit(prefill_fn)
        self._step = jax.jit(step_fn, donate_argnums=(1,))
        self._write = jax.jit(write_fn, donate_argnums=(0,))

    # ------------------------------------------------------------------ #
    # public API
    # ------------------------------------------------------------------ #
    def reset(self):
        """Clear all queue/slot/cache/metric state (keeps compiled fns).

        Request ids restart from 0 so a reset engine reproduces a fresh
        engine exactly — sampling keys fold the request id, so id reuse
        across resets is what makes reruns deterministic.
        """
        K = self.serve.max_slots
        self._next_id = 0
        self.cache = init_slot_cache(self.model, K, self.serve.max_seq)
        self.pool = SlotPool(K)
        self.metrics = ServeMetrics()
        self.queue: collections.deque = collections.deque()
        self.results: Dict[int, RequestResult] = {}
        self._tokens_by_req: Dict[int, List[int]] = {}
        self._live: Dict[int, Request] = {}     # admitted, not yet retired
        self._cur_tokens = np.zeros((K,), np.int32)
        self._active = np.zeros((K,), bool)
        self._rids = np.zeros((K,), np.int32)
        # device copies of the three slot vectors; re-uploaded only after
        # admission/retirement events (``_dirty``), so an event-free tick
        # costs exactly one dispatch + one (K,) sync
        self._dirty = True
        self._tokens_dev = None
        self._active_dev = None
        self._rids_dev = None

    def submit(self, prompt, max_new_tokens: Optional[int] = None,
               arrival_time: float = 0.0,
               eos_id: Optional[int] = None) -> int:
        """Queue a request; returns its request id.

        ``arrival_time`` is in seconds relative to the start of ``run()``;
        the scheduler will not admit the request before that time (this is
        how benchmark traces model Poisson arrivals).
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if prompt.size > self.serve.max_seq:
            raise ValueError(
                f"prompt of {prompt.size} tokens exceeds max_seq="
                f"{self.serve.max_seq}")
        rid = self._next_id
        self._next_id += 1
        budget = (self.serve.max_new_tokens if max_new_tokens is None
                  else max_new_tokens)
        if budget < 1:
            raise ValueError("max_new_tokens must be >= 1")
        self.queue.append(Request(request_id=rid, prompt=prompt,
                                  max_new_tokens=budget,
                                  arrival_time=arrival_time, eos_id=eos_id))
        self.metrics.on_submit(rid, prompt.size, arrival_time)
        self._tokens_by_req[rid] = []
        return rid

    def run(self, clock: Optional[Callable[[], float]] = None
            ) -> Dict[int, RequestResult]:
        """Drive the scheduler until every submitted request completes.

        ``clock`` (for tests) overrides the default wall clock, which is
        seconds since ``run()`` was called.  Generated tokens are
        clock-independent — the clock only gates admission times.
        """
        self.queue = collections.deque(
            sorted(self.queue, key=lambda r: r.arrival_time))
        t0 = time.perf_counter()
        now_fn = clock or (lambda: time.perf_counter() - t0)
        last_idle_now, stalled = None, 0
        while self.queue or self.pool.n_active:
            self._admit(now_fn)
            if self.pool.n_active:
                self._tick(now_fn)
                stalled = 0
                continue
            if not self.queue:
                break
            # idle: nothing decodable until the next arrival
            now = now_fn()
            if self.queue[0].arrival_time > now:
                if clock is None:
                    t_sleep = time.perf_counter()
                    time.sleep(min(self.queue[0].arrival_time - now, 0.05))
                    self.metrics.idle_wall += time.perf_counter() - t_sleep
                else:
                    # injected clocks must advance on their own; guard
                    # against a frozen clock turning this into a hang
                    stalled = stalled + 1 if now == last_idle_now else 0
                    if stalled > 1000:
                        raise RuntimeError(
                            "injected clock is not advancing past the next "
                            f"arrival_time ({self.queue[0].arrival_time}); "
                            "engine cannot make progress")
                last_idle_now = now
        # accumulate (not overwrite): timings persist across run() calls,
        # so throughput over multiple runs must divide by their total wall
        self.metrics.run_wall += now_fn()
        return dict(self.results)

    # ------------------------------------------------------------------ #
    # scheduler internals
    # ------------------------------------------------------------------ #
    def _admit(self, now_fn):
        """FCFS admission: fill free slots with arrived requests."""
        while (self.queue and self.pool.n_free
               and self.queue[0].arrival_time <= now_fn()):
            req = self.queue.popleft()
            slot = self.pool.acquire(req.request_id, req.prompt.size,
                                     req.max_new_tokens)
            logits, pcache = self._prefill(
                self.params, {"tokens": jnp.asarray(req.prompt)[None, :]})
            self.cache = self._write(self.cache, pcache["k"], pcache["v"],
                                     slot, req.prompt.size)
            # first generated token, drawn at position == prompt_len
            if self.serve.temperature > 0:
                key = sampling_key(self._base_key, req.request_id,
                                   req.prompt.size)
                tok = int(jax.random.categorical(
                    key, logits[0] / self.serve.temperature))
            else:
                tok = int(jnp.argmax(logits[0]))
            now = now_fn()
            self._live[req.request_id] = req
            self.metrics.on_admit(req.request_id, now)
            self.metrics.on_first_token(req.request_id, now)
            self._record_token(slot, req, tok, now)

    def _record_token(self, slot: int, req: Request, tok: int, now: float):
        """Append one generated token; retire the slot if finished."""
        state = self.pool.state(slot)
        toks = self._tokens_by_req[req.request_id]
        toks.append(tok)
        state.remaining -= 1
        # the token just recorded will occupy cache index prompt_len +
        # len(toks) - 1 on its decode tick; retire when that index would
        # fall outside the slot (cache full), on EOS, or on budget
        pos_next = state.prompt_len + len(toks) - 1
        done = (state.remaining <= 0
                or (req.eos_id is not None and tok == req.eos_id)
                or pos_next >= self.serve.max_seq)
        if done:
            self._retire(slot, req, now)
        else:
            if not self._active[slot]:
                self._dirty = True          # admission: slot newly active
            self._active[slot] = True
            self._cur_tokens[slot] = tok
            self._rids[slot] = req.request_id

    def _tick(self, now_fn):
        """One fused decode+sample step over every active slot."""
        if self._dirty:
            self._tokens_dev = jnp.asarray(self._cur_tokens)
            self._active_dev = jnp.asarray(self._active)
            self._rids_dev = jnp.asarray(self._rids)
            self._dirty = False
        toks_dev, self.cache = self._step(
            self.params, self.cache, self._tokens_dev, self._active_dev,
            self._rids_dev)
        toks = np.asarray(toks_dev)
        self.metrics.decode_ticks += 1
        now = now_fn()
        for slot in np.nonzero(self._active)[0]:
            slot = int(slot)
            rid = self.pool.state(slot).request_id
            self._record_token(slot, self._live[rid], int(toks[slot]), now)
        if not self._dirty:
            # no retirement this tick: the sampled tokens feed straight
            # back in without a host->device upload
            self._tokens_dev = toks_dev

    def _retire(self, slot: int, req: Request, now: float):
        """Release a finished slot and materialize its result."""
        if self._active[slot]:
            self._dirty = True
        self._active[slot] = False
        self.pool.release(slot)
        self._live.pop(req.request_id, None)
        toks = np.asarray(self._tokens_by_req[req.request_id], np.int32)
        self.metrics.on_complete(req.request_id, now,
                                 n_generated=int(toks.size))
        self.results[req.request_id] = RequestResult(
            request_id=req.request_id, prompt=req.prompt, tokens=toks,
            timing=self.metrics.timings[req.request_id])
