"""Continuous-batching serving engine over a slot-pool KV cache.

One ``ContinuousEngine`` owns a fixed ``max_slots x max_seq`` KV cache and
runs the scheduler loop::

    while queue or active slots:
        admit queued requests into free slots   (batched B=1 prefill each)
        one fused masked decode tick            (all active slots at once)
        sample one token per slot               (per-slot, per-position keys)
        retire finished slots                   (budget / EOS / cache full)

Requests of different prompt and generation lengths therefore share the
device batch: a short request retires and its slot is refilled from the
queue while long requests keep decoding — the decode batch stays full
instead of lockstepping to the longest sequence (the oneshot driver's
failure mode, kept in ``repro.serve.oneshot`` as the reference).

Quantized decode works unchanged: ``decode_slots`` routes each slot's
logits row through the quantizer-backend dispatcher
(``repro.quant.backend``) with the position-derived key
``fold_in(PRNGKey(17), 2*pos + 1)``, so ``--quant-fmt luq_fp4 --backend
pallas`` serves under continuous batching and a single greedy request
reproduces the oneshot tokens bit-for-bit.

Quantized KV cache (``ServeConfig.kv_fmt``): with ``int8`` / ``luq_fp4``
the slot pool stores code arrays plus per-(slot, token, kv-head) bf16
scales; prefill and decode write rows through the dispatched ``kv_quant``
op and attention runs through the dispatched ``decode_attn`` op (fused
dequant on the pallas backend).  Quantization is deterministic (no RNG),
so the engine stays token-identical to the oneshot driver at the same
``kv_fmt``.  On retirement the engine zeroes the slot's scale rows: zero
scale dequantizes every code to exactly 0, so a refilled slot can never
read a predecessor's rows against stale scales even before its own
writes land.

Prefill bucketing: admission pads each prompt to the next power of two
(clamped to ``max_seq``) and passes the true length as a *traced* scalar,
so the engine compiles at most ``ceil(log2(max_seq))`` prefill programs
instead of one per distinct prompt length.  Padding is
semantics-preserving: causal attention hides the pad from real rows, and
cache rows at index >= pos are masked until a decode tick overwrites
them (``prefill_programs`` exposes the jit cache size for tests).

Sampling key schedule (docs/SERVING.md): every sampled token uses
``fold_in(fold_in(fold_in(PRNGKey(seed), SAMPLE_FOLD), request_id),
position)`` — domain-separated from the quantizer streams by SAMPLE_FOLD,
and unique per (request, position) so concurrent slots never share a key.

Failure model (docs/SERVING.md "Failure model & recovery"): the engine is
hardened against per-request deadlines (timeout retirement with partial
results), queue overload (bounded queue + load shedding), and injected
faults (``runtime.faults.FaultPlan``: prefill/decode dispatch failures,
detected slot-cache poison, frozen clocks).  A fault victim is re-queued
with linear backoff and *replayed* by re-prefilling its prompt plus the
generated prefix recorded host-side — because sampling keys derive from
``(request_id, position)`` and KV quantization is deterministic, the
recovered request's tokens are bit-identical to a fault-free run.  Every
request retires with a typed status on its ``RequestResult``.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ServeConfig
from repro.launch.mesh import make_host_mesh
from repro.parallel import partitioner as pt
from repro.parallel.axes import partitioning_context
from repro.runtime.faults import DEFAULT_FREEZE_READS, FaultPlan
from repro.serve.metrics import ServeMetrics
from repro.serve.slots import SlotPool, init_slot_cache

# Domain-separation fold for sampling keys.  Chosen once and fixed: the
# quantizer streams fold small per-layer seeds (fake_quant) and the logits
# head folds 2*pos(+1) off PRNGKey(17), so a dedicated large fold off the
# *user* seed keeps the sampling stream disjoint from both.
SAMPLE_FOLD = 0x53A7


def prefill_bucket(prompt_len: int, max_seq: int) -> int:
    """Padded prefill length: next power of two, clamped to ``max_seq``.

    The floor of 2 merges the length-1 bucket into length-2, so the
    bucket set is {2, 4, ..., 2^ceil(log2(max_seq))} clamped — at most
    ``ceil(log2(max_seq))`` distinct prefill programs.
    """
    if prompt_len < 1 or prompt_len > max_seq:
        raise ValueError(f"prompt_len={prompt_len} outside [1, {max_seq}]")
    return min(max(2, 1 << (prompt_len - 1).bit_length()), max_seq)


def sampling_key(base_key: jax.Array, request_id, position) -> jax.Array:
    """Per-request, per-position sampling key (see module docstring).

    ``request_id`` and ``position`` may be python ints or traced int32
    scalars; distinct (request_id, position) pairs give distinct keys, so
    two slots decoding the same position draw independent bits.
    """
    k = jax.random.fold_in(base_key, SAMPLE_FOLD)
    k = jax.random.fold_in(k, request_id)
    return jax.random.fold_in(k, position)


@dataclasses.dataclass
class Request:
    """A queued generation request."""

    request_id: int
    prompt: np.ndarray              # (S,) int32 token ids
    max_new_tokens: int
    arrival_time: float = 0.0       # seconds relative to run() start
    eos_id: Optional[int] = None
    deadline_s: Optional[float] = None   # from arrival; None = no deadline
    attempts: int = 0               # fault-triggered re-queues so far
    not_before: float = 0.0         # retry backoff gate (seconds)

    def expiry(self) -> Optional[float]:
        """Absolute deadline instant, or None when unbounded."""
        if self.deadline_s is None:
            return None
        return self.arrival_time + self.deadline_s


@dataclasses.dataclass
class RequestResult:
    """Retired request: generated ids, timing record, terminal status.

    ``status`` is one of ``metrics.REQUEST_STATUSES``: "ok" (possibly
    after fault recovery), "timed_out" (deadline expired; ``tokens`` holds
    the partial result), "shed" (queue full at submit), or "failed" (fault
    retries exhausted; partial tokens).
    """

    request_id: int
    prompt: np.ndarray
    tokens: np.ndarray              # (n_generated,) int32
    timing: object                  # metrics.RequestTiming
    status: str = "ok"


class ContinuousEngine:
    """Slot-pool scheduler running fused masked decode over active slots.

    Parameters
    ----------
    model:
        A ``repro.models.registry.Model`` with the slot hooks
        (``decode_slots`` / ``slot_cache_spec``); currently the dense
        transformer family implements them.
    params:
        The model's parameter pytree.
    serve:
        ``repro.config.ServeConfig`` — slot count, cache length, sampling
        temperature and seed, plus the admission-control knobs (deadline,
        queue bound, retry policy).
    mesh:
        Optional ``jax.sharding.Mesh``; defaults to the host mesh.  The
        prefill/decode functions run under the same partitioning context
        the oneshot driver uses, so sharding annotations resolve
        identically.
    faults:
        Optional ``runtime.faults.FaultPlan``.  The engine polls it at its
        explicit hook points (prefill dispatch, decode tick, slot cache,
        clock reads) and recovers per the retry policy; every recovery
        path is therefore seed-reproducible.
    on_tick:
        Optional callback ``(tick_index, tick_wall_s, now_s)`` invoked
        after every decode-tick attempt — the supervisor's hook for
        heartbeat/straggler instrumentation (``runtime.supervisor``).
    """

    def __init__(self, model, params, serve: ServeConfig, mesh=None,
                 faults: Optional[FaultPlan] = None,
                 on_tick: Optional[Callable[[int, float, float], None]] = None):
        """Allocate the slot cache and jit the engine's device functions."""
        if model.decode_slots is None or model.slot_cache_spec is None:
            raise ValueError(
                f"model family {model.config.family!r} does not support "
                "continuous batching (no decode_slots/slot_cache_spec)")
        extra = set(model.batch_spec(1, 2)) - {"tokens"}
        if extra:
            # fail at construction, not deep inside prefill at admission:
            # _admit builds {"tokens": prompt} only, so families whose
            # batch_spec needs more inputs (encdec enc_embeds, vlm vision
            # embeds) need a prompt-to-batch hook before they can ride the
            # slot engine
            raise ValueError(
                f"continuous batching supports token-only prompts; family "
                f"{model.config.family!r} also requires {sorted(extra)}")
        if serve.kv_fmt not in model.kv_formats:
            raise ValueError(
                f"model family {model.config.family!r} does not support "
                f"kv_fmt={serve.kv_fmt!r} (supported: {model.kv_formats})")
        self.model = model
        self.params = params
        self.serve = serve
        self.faults = faults
        self.on_tick = on_tick
        self.mesh = mesh if mesh is not None else make_host_mesh()
        rules = pt.merge_rules(pt.DEFAULT_RULES,
                               model.config.sharding_overrides)
        self._resolver = pt.activation_resolver(self.mesh, rules)
        self._base_key = jax.random.PRNGKey(serve.seed)
        self._jit_fns()
        self.reset()

    # ------------------------------------------------------------------ #
    # device functions
    # ------------------------------------------------------------------ #
    def _jit_fns(self):
        """Build the jitted prefill / cache-write / decode / sample fns."""
        model, resolver = self.model, self._resolver
        temperature, base_key = self.serve.temperature, self._base_key
        kv_fmt = self.serve.kv_fmt
        kv_kw = {} if kv_fmt == "none" else {"kv_fmt": kv_fmt}

        def prefill_fn(params, batch, prompt_len):
            # prompt_len is a traced scalar: the token batch is padded to a
            # power-of-two bucket (prefill_bucket), so the compiled program
            # depends only on the bucket, never on the exact prompt length
            with partitioning_context(resolver):
                return model.prefill(params, batch, prompt_len=prompt_len,
                                     **kv_kw)

        def step_fn(params, cache, tokens, active, rids):
            # fused decode + sample: one dispatch and one (K,) device->host
            # transfer per tick (the (K, V) logits never leave the device)
            with partitioning_context(resolver):
                logits, cache = model.decode_slots(params, cache, tokens,
                                                   active, **kv_kw)
            pos = cache["pos"]
            if temperature > 0:
                keys = jax.vmap(
                    lambda r, p: sampling_key(base_key, r, p))(rids, pos)
                toks = jax.vmap(lambda k, row: jax.random.categorical(
                    k, row / temperature))(keys, logits)
            else:
                toks = jnp.argmax(logits, -1)
            return toks.astype(jnp.int32), cache

        def write_fn(cache, pcache, slot):
            # copy every prefill cache array (codes and, when quantized,
            # scales) into the slot's rows; the prefill batch axis is 1 and
            # its seq extent is the bucket length <= max_seq, so one
            # dynamic_update_slice per array covers every layout
            out = {}
            for name, arr in cache.items():
                if name == "pos":
                    out[name] = arr.at[slot].set(pcache["pos"])
                    continue
                upd = pcache[name].astype(arr.dtype)
                start = (0, slot) + (0,) * (arr.ndim - 2)
                out[name] = jax.lax.dynamic_update_slice(arr, upd, start)
            return out

        def release_fn(cache, slot):
            # zero the retiring slot's scale rows: zero scale dequantizes
            # every code to exactly 0, so the next occupant can never read
            # the predecessor's rows against stale scales (the codes
            # themselves are harmless without their scales and are masked
            # by pos regardless)
            out = dict(cache)
            for name in ("k_scale", "v_scale"):
                arr = cache[name]
                zeros = jnp.zeros((arr.shape[0], 1) + arr.shape[2:],
                                  arr.dtype)
                out[name] = jax.lax.dynamic_update_slice(
                    arr, zeros, (0, slot) + (0,) * (arr.ndim - 2))
            return out

        # prefill compiles once per power-of-two bucket (prefill_bucket);
        # step/write/release compile once for the slot geometry
        self._prefill = jax.jit(prefill_fn)
        self._step = jax.jit(step_fn, donate_argnums=(1,))
        self._write = jax.jit(write_fn, donate_argnums=(0,))
        self._release_scales = (jax.jit(release_fn, donate_argnums=(0,))
                                if kv_fmt != "none" else None)

    @property
    def prefill_programs(self) -> int:
        """Number of distinct prefill programs compiled so far.

        Bounded by ``ceil(log2(max_seq))`` for any mix of prompt lengths —
        the bucketing invariant tests assert against.
        """
        return self._prefill._cache_size()

    # ------------------------------------------------------------------ #
    # public API
    # ------------------------------------------------------------------ #
    def reset(self):
        """Clear all queue/slot/cache/metric state (keeps compiled fns).

        Request ids restart from 0 so a reset engine reproduces a fresh
        engine exactly — sampling keys fold the request id, so id reuse
        across resets is what makes reruns deterministic.
        """
        K = self.serve.max_slots
        self._next_id = 0
        self.cache = init_slot_cache(self.model, K, self.serve.max_seq,
                                     kv_fmt=self.serve.kv_fmt)
        self.pool = SlotPool(K)
        self.metrics = ServeMetrics()
        self.queue: collections.deque = collections.deque()
        self.results: Dict[int, RequestResult] = {}
        self._tokens_by_req: Dict[int, List[int]] = {}
        self._live: Dict[int, Request] = {}     # admitted, not yet retired
        self._cur_tokens = np.zeros((K,), np.int32)
        self._active = np.zeros((K,), bool)
        self._rids = np.zeros((K,), np.int32)
        # fault-tolerance state: per-domain counters the FaultPlan is
        # polled against, the clock-freeze window, and the degraded-mode
        # admission cap (shrunk by the supervisor on replica loss)
        self._tick_index = 0
        self._prefill_count = 0
        self._freeze_reads = 0
        self._freeze_val = 0.0
        self.slot_cap = K
        # device copies of the three slot vectors; re-uploaded only after
        # admission/retirement events (``_dirty``), so an event-free tick
        # costs exactly one dispatch + one (K,) sync
        self._dirty = True
        self._tokens_dev = None
        self._active_dev = None
        self._rids_dev = None

    def submit(self, prompt, max_new_tokens: Optional[int] = None,
               arrival_time: float = 0.0,
               eos_id: Optional[int] = None,
               deadline_s: Optional[float] = None) -> int:
        """Queue a request; returns its request id.

        ``arrival_time`` is in seconds relative to the start of ``run()``;
        the scheduler will not admit the request before that time (this is
        how benchmark traces model Poisson arrivals).  ``deadline_s``
        (default ``ServeConfig.deadline_s``) bounds the request's life from
        arrival: expiry in the queue rejects it un-admitted, expiry in
        flight retires it with partial tokens (status "timed_out").

        When ``ServeConfig.max_queue`` > 0 and that many requests are
        already waiting, the request is *shed*: it is never queued, its
        result (status "shed", no tokens) is recorded immediately, and the
        shed counter increments — bounded memory under overload instead of
        unbounded queue growth.
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if prompt.size > self.serve.max_seq:
            raise ValueError(
                f"prompt of {prompt.size} tokens exceeds max_seq="
                f"{self.serve.max_seq}")
        rid = self._next_id
        self._next_id += 1
        budget = (self.serve.max_new_tokens if max_new_tokens is None
                  else max_new_tokens)
        if budget < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if deadline_s is None:
            deadline_s = self.serve.deadline_s
        self.metrics.on_submit(rid, prompt.size, arrival_time)
        self._tokens_by_req[rid] = []
        req = Request(request_id=rid, prompt=prompt, max_new_tokens=budget,
                      arrival_time=arrival_time, eos_id=eos_id,
                      deadline_s=deadline_s)
        if (self.serve.max_queue > 0
                and len(self.queue) >= self.serve.max_queue):
            self.metrics.on_shed(rid, arrival_time)
            self.results[rid] = RequestResult(
                request_id=rid, prompt=prompt,
                tokens=np.zeros((0,), np.int32),
                timing=self.metrics.timings[rid], status="shed")
            return rid
        self.queue.append(req)
        return rid

    def run(self, clock: Optional[Callable[[], float]] = None
            ) -> Dict[int, RequestResult]:
        """Drive the scheduler until every submitted request completes.

        ``clock`` (for tests) overrides the default wall clock, which is
        seconds since ``run()`` was called.  Generated tokens are
        clock-independent — the clock only gates admission times.
        """
        self.queue = collections.deque(
            sorted(self.queue, key=lambda r: r.arrival_time))
        t0 = time.perf_counter()
        raw_now = clock or (lambda: time.perf_counter() - t0)

        def now_fn():
            # clock_freeze fault: hold time still for the injected window
            # (a bounded number of *reads*, so the loop always thaws well
            # before the frozen-clock stall guard below can trip)
            if self._freeze_reads > 0:
                self._freeze_reads -= 1
                return self._freeze_val
            return raw_now()

        last_idle_now, stalled = None, 0
        try:
            while self.queue or self.pool.n_active:
                self._expire_deadlines(now_fn)
                self._admit(now_fn)
                if self.pool.n_active:
                    self._tick(now_fn)
                    stalled = 0
                    continue
                if not self.queue:
                    break
                # idle: nothing decodable until the next eligible request
                # (arrival in the future, or retry backoff gate not open)
                now = now_fn()
                next_ready = min(max(r.arrival_time, r.not_before)
                                 for r in self.queue)
                if next_ready > now:
                    if clock is None:
                        t_sleep = time.perf_counter()
                        time.sleep(min(next_ready - now, 0.05))
                        self.metrics.idle_wall += (time.perf_counter()
                                                   - t_sleep)
                    else:
                        # injected clocks must advance on their own; guard
                        # against a frozen clock turning this into a hang
                        stalled = stalled + 1 if now == last_idle_now else 0
                        if stalled > 1000:
                            raise RuntimeError(
                                "injected clock is not advancing past the "
                                f"next eligible time ({next_ready}); engine "
                                "cannot make progress")
                    last_idle_now = now
        finally:
            # accumulate (not overwrite): timings persist across run()
            # calls, so throughput over multiple runs must divide by their
            # total wall.  raw_now sidesteps any still-open freeze window.
            self.metrics.run_wall += raw_now()
        return dict(self.results)

    # ------------------------------------------------------------------ #
    # scheduler internals
    # ------------------------------------------------------------------ #
    def _next_eligible(self, now: float) -> Optional[Request]:
        """Pop the first queued request that may run now (FCFS order).

        Eligibility = arrived (``arrival_time <= now``) and past its retry
        backoff gate (``not_before <= now``).  Returns None when nothing
        is eligible yet.
        """
        for i, req in enumerate(self.queue):
            if req.arrival_time <= now and req.not_before <= now:
                del self.queue[i]
                return req
        return None

    def _admit(self, now_fn):
        """FCFS admission: fill free slots with eligible requests.

        Prompts are zero-padded to their power-of-two bucket
        (``prefill_bucket``) before prefill, with the true length passed
        as a traced scalar — one compiled prefill program per bucket.

        A *replayed* request (fault victim, ``attempts > 0``) is
        re-admitted by prefilling its prompt concatenated with the
        generated prefix recorded host-side; the first fresh token is then
        sampled at position ``prompt_len + len(prefix)`` with the same
        ``(request_id, position)`` key a fault-free run would have used,
        so recovery is token-bit-identical.  ``SlotState.prompt_len``
        keeps the *original* prompt length so the cache-index/retirement
        arithmetic in ``_record_token`` is invariant under replay.

        Admission is capped at ``slot_cap`` (<= max_slots); the supervisor
        shrinks it in degraded mode after replica loss.
        """
        while self.pool.n_free and self.pool.n_active < self.slot_cap:
            req = self._next_eligible(now_fn())
            if req is None:
                return
            prefix = self._tokens_by_req[req.request_id]
            total = req.prompt.size + len(prefix)
            if self.faults is not None:
                attempt = self._prefill_count
                self._prefill_count += 1
                due = self.faults.take("prefill_fail", attempt)
                if due:
                    # injected prefill dispatch failure: the request never
                    # touches a slot; re-queue it behind its backoff gate
                    self.metrics.faults_injected += len(due)
                    self._requeue(req, now_fn())
                    continue
            slot = self.pool.acquire(req.request_id, req.prompt.size,
                                     req.max_new_tokens - len(prefix))
            bucket = prefill_bucket(total, self.serve.max_seq)
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :req.prompt.size] = req.prompt
            if prefix:
                padded[0, req.prompt.size:total] = prefix
            logits, pcache = self._prefill(
                self.params, {"tokens": jnp.asarray(padded)}, total)
            self.cache = self._write(self.cache, pcache, slot)
            # first generated token, drawn at position == total sequence
            # length so far (== prompt_len on a fresh admission)
            if self.serve.temperature > 0:
                key = sampling_key(self._base_key, req.request_id, total)
                tok = int(jax.random.categorical(
                    key, logits[0] / self.serve.temperature))
            else:
                tok = int(jnp.argmax(logits[0]))
            now = now_fn()
            self._live[req.request_id] = req
            self.metrics.on_admit(req.request_id, now)
            self.metrics.on_first_token(req.request_id, now)
            self._record_token(slot, req, tok, now)

    def _record_token(self, slot: int, req: Request, tok: int, now: float):
        """Append one generated token; retire the slot if finished."""
        state = self.pool.state(slot)
        toks = self._tokens_by_req[req.request_id]
        toks.append(tok)
        state.remaining -= 1
        # the token just recorded will occupy cache index prompt_len +
        # len(toks) - 1 on its decode tick; retire when that index would
        # fall outside the slot (cache full), on EOS, or on budget
        pos_next = state.prompt_len + len(toks) - 1
        done = (state.remaining <= 0
                or (req.eos_id is not None and tok == req.eos_id)
                or pos_next >= self.serve.max_seq)
        if done:
            self._retire(slot, req, now)
        else:
            if not self._active[slot]:
                self._dirty = True          # admission: slot newly active
            self._active[slot] = True
            self._cur_tokens[slot] = tok
            self._rids[slot] = req.request_id

    def _tick(self, now_fn):
        """One fused decode+sample step over every active slot.

        Fault hook point: ``clock_freeze`` / ``slot_corrupt`` /
        ``decode_fail`` events are polled against the tick counter before
        the fused step runs; a decode failure victimizes every active slot
        (the whole fused dispatch failed) and re-queues them for replay.
        ``on_tick`` fires after every attempt — including failed ones —
        with the tick's real wall time, which is what the supervisor's
        heartbeat/straggler instrumentation consumes.
        """
        tick = self._tick_index
        self._tick_index += 1
        t_start = time.perf_counter()
        try:
            if self.faults is not None:
                for ev in self.faults.take("clock_freeze", tick):
                    self.metrics.faults_injected += 1
                    # read the instant *before* opening the window so the
                    # frozen value is the current time, then hold it for
                    # the next `duration` reads
                    self._freeze_val = now_fn()
                    self._freeze_reads = ev.duration or DEFAULT_FREEZE_READS
                for ev in self.faults.take("slot_corrupt", tick):
                    self.metrics.faults_injected += 1
                    self.metrics.slot_faults += 1
                    self._corrupt_slot(ev, now_fn)
                due = self.faults.take("decode_fail", tick)
                if due:
                    self.metrics.faults_injected += len(due)
                    self.metrics.slot_faults += len(due)
                    self._fail_tick(now_fn)
                    return
                if not self.pool.n_active:
                    # every occupant was a corruption victim; nothing to
                    # decode this tick
                    return
            if self._dirty:
                self._tokens_dev = jnp.asarray(self._cur_tokens)
                self._active_dev = jnp.asarray(self._active)
                self._rids_dev = jnp.asarray(self._rids)
                self._dirty = False
            toks_dev, self.cache = self._step(
                self.params, self.cache, self._tokens_dev, self._active_dev,
                self._rids_dev)
            toks = np.asarray(toks_dev)
            self.metrics.decode_ticks += 1
            now = now_fn()
            for slot in np.nonzero(self._active)[0]:
                slot = int(slot)
                rid = self.pool.state(slot).request_id
                self._record_token(slot, self._live[rid], int(toks[slot]),
                                   now)
            if not self._dirty:
                # no retirement this tick: the sampled tokens feed straight
                # back in without a host->device upload
                self._tokens_dev = toks_dev
        finally:
            if self.on_tick is not None:
                self.on_tick(tick, time.perf_counter() - t_start, now_fn())

    # ------------------------------------------------------------------ #
    # fault recovery
    # ------------------------------------------------------------------ #
    def _evict(self, slot: int) -> Request:
        """Tear a live request out of ``slot`` without finalizing it."""
        rid = self.pool.state(slot).request_id
        req = self._live.pop(rid)
        self._active[slot] = False
        self._dirty = True
        self.pool.release(slot)
        if self._release_scales is not None:
            self.cache = self._release_scales(self.cache, slot)
        return req

    def _requeue(self, req: Request, now: float):
        """Re-queue a fault victim with linear backoff (or fail it out).

        The generated prefix stays in ``_tokens_by_req``; re-admission
        replays it (see ``_admit``).  When the retry budget is exhausted
        the request retires with status "failed" and its partial tokens.
        """
        req.attempts += 1
        if req.attempts > self.serve.max_retries:
            self._finalize(req, now, status="failed")
            return
        req.not_before = now + req.attempts * self.serve.retry_backoff_s
        self.metrics.on_retry(req.request_id)
        self.queue.append(req)

    def _fail_tick(self, now_fn):
        """Injected decode dispatch failure: all active slots are victims."""
        now = now_fn()
        for slot in np.nonzero(self._active)[0]:
            self._requeue(self._evict(int(slot)), now)

    def _corrupt_slot(self, ev, now_fn):
        """Overwrite one slot's cache rows with deterministic garbage.

        Modelled as *detected* poison (ECC-style): the scrubber knows the
        slot is bad, so the occupant (if any) is evicted for deterministic
        replay and the slot's scale rows are zeroed before reuse.  Under
        ``kv_fmt=none`` (no scale rows) the garbage codes are neutralized
        by pos-masking plus the next occupant's prefill overwrite.
        """
        K = self.serve.max_slots
        slot = ev.target % K if ev.target >= 0 else 0
        rng = np.random.default_rng((self.faults.seed, ev.at, slot))
        cache = dict(self.cache)
        for name, arr in cache.items():
            if name == "pos":
                continue
            junk = rng.integers(-100, 100,
                                size=(arr.shape[0], 1) + arr.shape[2:])
            cache[name] = jax.lax.dynamic_update_slice(
                arr, jnp.asarray(junk).astype(arr.dtype),
                (0, slot) + (0,) * (arr.ndim - 2))
        self.cache = cache
        if self._active[slot]:
            self._requeue(self._evict(slot), now_fn())
        elif self._release_scales is not None:
            self.cache = self._release_scales(self.cache, slot)

    def _expire_deadlines(self, now_fn):
        """Retire every request whose deadline has passed.

        Queued requests that were never admitted land in the metrics'
        rejected bucket (``on_queue_timeout``); previously-admitted
        victims awaiting replay, and in-flight requests, retire with
        status "timed_out" and whatever tokens they generated.
        """
        if not self.queue and not self._live:
            return
        now = now_fn()
        keep: collections.deque = collections.deque()
        for req in self.queue:
            exp = req.expiry()
            if exp is None or exp > now:
                keep.append(req)
                continue
            self._finalize(req, now, status="timed_out")
        self.queue = keep
        for slot in np.nonzero(self._active)[0]:
            slot = int(slot)
            req = self._live[self.pool.state(slot).request_id]
            exp = req.expiry()
            if exp is not None and exp <= now:
                self._retire(slot, req, now, status="timed_out")

    def _finalize(self, req: Request, now: float, status: str):
        """Materialize a terminal result for a request not holding a slot."""
        rid = req.request_id
        toks = np.asarray(self._tokens_by_req.get(rid, []), np.int32)
        if status == "timed_out" and self.metrics.timings[rid].admitted is None:
            self.metrics.on_queue_timeout(rid, now)
        else:
            self.metrics.on_complete(rid, now, n_generated=int(toks.size),
                                     status=status)
        self.results[rid] = RequestResult(
            request_id=rid, prompt=req.prompt, tokens=toks,
            timing=self.metrics.timings[rid], status=status)

    # ------------------------------------------------------------------ #
    # degraded-mode hooks (runtime.supervisor)
    # ------------------------------------------------------------------ #
    def set_slot_cap(self, cap: int):
        """Cap concurrent admissions (degraded mode); clamped to [1, K]."""
        self.slot_cap = max(1, min(int(cap), self.serve.max_slots))

    def takeover_unfinished(self) -> List[Tuple[Request, List[int]]]:
        """Drain every unfinished request for an external driver.

        Evicts all live slots and empties the queue, returning
        ``(request, generated_prefix)`` pairs in request-id order.  The
        supervisor's oneshot fallback finishes each with the *engine's*
        sampling-key schedule and reports results via
        ``finalize_external`` — tokens stay bit-identical to a fault-free
        continuous run.
        """
        out = []
        for slot in np.nonzero(self._active)[0]:
            req = self._evict(int(slot))
            out.append((req, list(self._tokens_by_req[req.request_id])))
        while self.queue:
            req = self.queue.popleft()
            out.append((req, list(self._tokens_by_req[req.request_id])))
        return sorted(out, key=lambda p: p[0].request_id)

    def finalize_external(self, req: Request, tokens, now: float,
                          status: str = "ok"):
        """Record a result completed outside the engine (oneshot fallback)."""
        self._tokens_by_req[req.request_id] = [int(t) for t in tokens]
        self._finalize(req, now, status=status)

    def _retire(self, slot: int, req: Request, now: float,
                status: str = "ok"):
        """Release a finished slot and materialize its result."""
        if self._active[slot]:
            self._dirty = True
        self._active[slot] = False
        self.pool.release(slot)
        if self._release_scales is not None:
            # quantized cache: invalidate the slot's scale rows so the next
            # occupant can never dequantize this occupant's leftovers
            self.cache = self._release_scales(self.cache, slot)
        self._live.pop(req.request_id, None)
        toks = np.asarray(self._tokens_by_req[req.request_id], np.int32)
        self.metrics.on_complete(req.request_id, now,
                                 n_generated=int(toks.size), status=status)
        self.results[req.request_id] = RequestResult(
            request_id=req.request_id, prompt=req.prompt, tokens=toks,
            timing=self.metrics.timings[req.request_id], status=status)
