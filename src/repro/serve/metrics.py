"""Serving metrics: per-request timings and fleet-level throughput/latency.

The engine stamps four events per request — arrival (submit), admission
(slot acquired + prefill), first token, completion — and this module turns
them into the numbers a serving benchmark reports: tokens/sec over the run,
and p50/p99 of end-to-end latency, time-to-first-token, and queue wait.
All times are seconds on whatever clock the engine uses (wall clock by
default; tests may inject a fake clock).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class RequestTiming:
    """Event timestamps and token counts for one request."""

    request_id: int
    prompt_len: int
    arrival: float
    admitted: Optional[float] = None
    first_token: Optional[float] = None
    completed: Optional[float] = None
    n_generated: int = 0

    @property
    def queue_wait(self) -> float:
        """Seconds spent queued before a slot freed up."""
        return self.admitted - self.arrival

    @property
    def ttft(self) -> float:
        """Time to first token, from arrival."""
        return self.first_token - self.arrival

    @property
    def latency(self) -> float:
        """End-to-end seconds from arrival to the last token."""
        return self.completed - self.arrival


class ServeMetrics:
    """Accumulates per-request timings and summarizes a serving run."""

    def __init__(self):
        """Start with an empty timing table."""
        self.timings: Dict[int, RequestTiming] = {}
        self.decode_ticks = 0
        # both walls accumulate across run() calls (reset() clears them):
        # run_wall = total scheduler-loop time, idle_wall = the part spent
        # sleeping for future arrivals (no decodable work)
        self.run_wall: float = 0.0
        self.idle_wall: float = 0.0

    def on_submit(self, request_id: int, prompt_len: int,
                  arrival: float) -> None:
        """Record a request entering the queue."""
        self.timings[request_id] = RequestTiming(
            request_id=request_id, prompt_len=prompt_len, arrival=arrival)

    def on_admit(self, request_id: int, now: float) -> None:
        """Record slot acquisition (prefill happens at admission)."""
        self.timings[request_id].admitted = now

    def on_first_token(self, request_id: int, now: float) -> None:
        """Record the first generated token."""
        self.timings[request_id].first_token = now

    def on_complete(self, request_id: int, now: float,
                    n_generated: int) -> None:
        """Record retirement with the request's generated-token count."""
        t = self.timings[request_id]
        t.completed = now
        t.n_generated = n_generated

    def _done(self) -> List[RequestTiming]:
        return [t for t in self.timings.values() if t.completed is not None]

    def per_request(self) -> List[dict]:
        """Per-request timing rows (completed requests, by request id).

        One dict per request with its TTFT / latency / queue wait in
        seconds — the raw rows behind ``summary()``'s percentiles, which
        benchmarks embed in their JSON so regressions are attributable to
        specific requests rather than buried in an aggregate.
        """
        return [{
            "request_id": t.request_id,
            "prompt_len": t.prompt_len,
            "n_generated": t.n_generated,
            "ttft_s": t.ttft,
            "latency_s": t.latency,
            "queue_wait_s": t.queue_wait,
        } for t in sorted(self._done(), key=lambda t: t.request_id)]

    def summary(self) -> dict:
        """Aggregate throughput and latency percentiles for completed work.

        ``tokens_per_sec`` counts *generated* tokens only (prompt tokens are
        input, not output) over ``run_wall``, which the engine sets to the
        full scheduler-loop wall time.
        """
        done = self._done()
        if not done:
            # same key set as the populated branch so callers can index
            # unconditionally
            return {"n_requests": 0, "total_new_tokens": 0,
                    "run_wall_s": self.run_wall,
                    "idle_wall_s": self.idle_wall,
                    "tokens_per_sec": 0.0,
                    "decode_ticks": self.decode_ticks,
                    "latency_p50_s": 0.0, "latency_p99_s": 0.0,
                    "ttft_p50_s": 0.0, "ttft_p99_s": 0.0,
                    "queue_wait_p50_s": 0.0, "queue_wait_p99_s": 0.0}
        lat = np.array([t.latency for t in done])
        ttft = np.array([t.ttft for t in done])
        wait = np.array([t.queue_wait for t in done])
        total_new = int(sum(t.n_generated for t in done))
        wall = self.run_wall or max(t.completed for t in done) - min(
            t.arrival for t in done)
        return {
            "n_requests": len(done),
            "total_new_tokens": total_new,
            "run_wall_s": wall,
            "idle_wall_s": self.idle_wall,
            "tokens_per_sec": total_new / max(wall, 1e-9),
            "decode_ticks": self.decode_ticks,
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p99_s": float(np.percentile(lat, 99)),
            "ttft_p50_s": float(np.percentile(ttft, 50)),
            "ttft_p99_s": float(np.percentile(ttft, 99)),
            "queue_wait_p50_s": float(np.percentile(wait, 50)),
            "queue_wait_p99_s": float(np.percentile(wait, 99)),
        }
