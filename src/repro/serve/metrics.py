"""Serving metrics: per-request timings and fleet-level throughput/latency.

The engine stamps four events per request — arrival (submit), admission
(slot acquired + prefill), first token, completion — and this module turns
them into the numbers a serving benchmark reports: tokens/sec over the run,
and p50/p99 of end-to-end latency, time-to-first-token, and queue wait.
All times are seconds on whatever clock the engine uses (wall clock by
default; tests may inject a fake clock).

Fault tolerance (docs/SERVING.md "Failure model & recovery") adds a
``status`` to every request and a set of recovery counters:

* ``ok`` — completed normally (possibly after retries: ``recovered``);
* ``timed_out`` — deadline expired, either in the queue (never admitted)
  or in flight (retired with partial tokens);
* ``shed`` — rejected at submit because the queue was at ``max_queue``;
* ``failed`` — a fault victim whose retry budget ran out.

Requests that never produced tokens (``shed``, queue-expired
``timed_out``) have ``admitted``/``first_token``/``completed`` = None and
are reported through ``rejected()`` — ``summary()`` and ``per_request()``
never crash on them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

#: Terminal request states a RequestTiming / RequestResult may carry.
REQUEST_STATUSES = ("ok", "timed_out", "shed", "failed")


@dataclasses.dataclass
class RequestTiming:
    """Event timestamps, token counts, and terminal status for one request."""

    request_id: int
    prompt_len: int
    arrival: float
    admitted: Optional[float] = None
    first_token: Optional[float] = None
    completed: Optional[float] = None
    n_generated: int = 0
    status: str = "ok"
    retries: int = 0

    @property
    def queue_wait(self) -> Optional[float]:
        """Seconds spent queued before a slot freed up (None if never)."""
        if self.admitted is None:
            return None
        return self.admitted - self.arrival

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token, from arrival (None if none was produced)."""
        if self.first_token is None:
            return None
        return self.first_token - self.arrival

    @property
    def latency(self) -> Optional[float]:
        """End-to-end seconds from arrival to retirement (None if open)."""
        if self.completed is None:
            return None
        return self.completed - self.arrival


class ServeMetrics:
    """Accumulates per-request timings and summarizes a serving run."""

    def __init__(self):
        """Start with an empty timing table and zeroed counters."""
        self.timings: Dict[int, RequestTiming] = {}
        self.decode_ticks = 0
        # both walls accumulate across run() calls (reset() clears them):
        # run_wall = total scheduler-loop time, idle_wall = the part spent
        # sleeping for future arrivals (no decodable work)
        self.run_wall: float = 0.0
        self.idle_wall: float = 0.0
        # ---- fault-tolerance counters (docs/SERVING.md) ----
        self.shed = 0               # rejected at submit (queue full)
        self.retried = 0            # re-queue events after a fault
        self.deadline_missed = 0    # queued + in-flight deadline expiries
        self.recovered = 0          # requests that completed ok after >=1 retry
        self.faults_injected = 0    # FaultPlan events that actually fired
        self.slot_faults = 0        # slot-pool faults (corruption/decode)
        self.degraded_events = 0    # supervisor re-plans (death/straggler)

    def on_submit(self, request_id: int, prompt_len: int,
                  arrival: float) -> None:
        """Record a request entering the queue."""
        self.timings[request_id] = RequestTiming(
            request_id=request_id, prompt_len=prompt_len, arrival=arrival)

    def on_admit(self, request_id: int, now: float) -> None:
        """Record slot acquisition (first admission only: retries keep the
        original admission stamp so queue_wait measures the first wait)."""
        t = self.timings[request_id]
        if t.admitted is None:
            t.admitted = now

    def on_first_token(self, request_id: int, now: float) -> None:
        """Record the first generated token (first admission only)."""
        t = self.timings[request_id]
        if t.first_token is None:
            t.first_token = now

    def on_retry(self, request_id: int) -> None:
        """Record one fault-triggered re-queue of ``request_id``."""
        self.retried += 1
        self.timings[request_id].retries += 1

    def on_shed(self, request_id: int, now: float) -> None:
        """Record a submit-time rejection (queue at max_queue)."""
        self.shed += 1
        self.timings[request_id].status = "shed"

    def on_complete(self, request_id: int, now: float,
                    n_generated: int, status: str = "ok") -> None:
        """Record retirement with the request's generated-token count."""
        if status not in REQUEST_STATUSES:
            raise ValueError(f"unknown request status {status!r}")
        t = self.timings[request_id]
        t.completed = now
        t.n_generated = n_generated
        t.status = status
        if status == "timed_out":
            self.deadline_missed += 1
        if status == "ok" and t.retries > 0:
            self.recovered += 1

    def on_queue_timeout(self, request_id: int, now: float) -> None:
        """Record a deadline expiry of a request still in the queue.

        The request was never admitted, so ``admitted``/``first_token``
        stay None and the row lands in ``rejected()``.
        """
        t = self.timings[request_id]
        t.status = "timed_out"
        self.deadline_missed += 1

    def _done(self) -> List[RequestTiming]:
        """Requests that were admitted and retired (any terminal status)."""
        return [t for t in self.timings.values() if t.completed is not None]

    def _rejected(self) -> List[RequestTiming]:
        """Requests that terminated without ever being admitted."""
        return [t for t in self.timings.values()
                if t.completed is None and t.status != "ok"]

    def per_request(self) -> List[dict]:
        """Per-request timing rows (admitted + retired, by request id).

        One dict per request with its TTFT / latency / queue wait in
        seconds plus terminal ``status`` and ``retries`` — the raw rows
        behind ``summary()``'s percentiles, which benchmarks embed in
        their JSON so regressions are attributable to specific requests
        rather than buried in an aggregate.  Never-admitted requests
        (shed / queue-expired) are reported by ``rejected()`` instead.
        """
        return [{
            "request_id": t.request_id,
            "prompt_len": t.prompt_len,
            "n_generated": t.n_generated,
            "status": t.status,
            "retries": t.retries,
            "ttft_s": t.ttft,
            "latency_s": t.latency,
            "queue_wait_s": t.queue_wait,
        } for t in sorted(self._done(), key=lambda t: t.request_id)]

    def rejected(self) -> List[dict]:
        """Rows for shed / never-admitted timed-out requests.

        These have no admission, first-token, or completion stamps; only
        identity, arrival, and the rejection status are meaningful.
        """
        return [{
            "request_id": t.request_id,
            "prompt_len": t.prompt_len,
            "arrival_s": t.arrival,
            "status": t.status,
        } for t in sorted(self._rejected(), key=lambda t: t.request_id)]

    def summary(self) -> dict:
        """Aggregate throughput and latency percentiles for completed work.

        ``tokens_per_sec`` counts *generated* tokens only (prompt tokens are
        input, not output) over ``run_wall``, which the engine sets to the
        full scheduler-loop wall time.  Percentiles cover ``status == "ok"``
        completions; shed / timed-out / failed requests are counted in
        their own buckets so they can't silently skew the latency story.
        """
        done = self._done()
        ok = [t for t in done if t.status == "ok"]
        counters = {
            "shed": self.shed,
            "retried": self.retried,
            "deadline_missed": self.deadline_missed,
            "recovered": self.recovered,
            "faults_injected": self.faults_injected,
            "slot_faults": self.slot_faults,
            "degraded_events": self.degraded_events,
            "n_timed_out": sum(1 for t in self.timings.values()
                               if t.status == "timed_out"),
            "n_failed": sum(1 for t in done if t.status == "failed"),
            "n_rejected": len(self._rejected()),
        }
        if not ok:
            # same key set as the populated branch so callers can index
            # unconditionally
            return {"n_requests": 0,
                    "total_new_tokens": int(sum(t.n_generated for t in done)),
                    "run_wall_s": self.run_wall,
                    "idle_wall_s": self.idle_wall,
                    "tokens_per_sec": 0.0,
                    "decode_ticks": self.decode_ticks,
                    "latency_p50_s": 0.0, "latency_p99_s": 0.0,
                    "ttft_p50_s": 0.0, "ttft_p99_s": 0.0,
                    "queue_wait_p50_s": 0.0, "queue_wait_p99_s": 0.0,
                    **counters}
        lat = np.array([t.latency for t in ok])
        ttft = np.array([t.ttft for t in ok if t.ttft is not None])
        wait = np.array([t.queue_wait for t in ok])
        # all retired tokens count as produced work (a timed-out request's
        # partial tokens were still generated and returned)
        total_new = int(sum(t.n_generated for t in done))
        wall = self.run_wall or max(t.completed for t in done) - min(
            t.arrival for t in done)
        return {
            "n_requests": len(ok),
            "total_new_tokens": total_new,
            "run_wall_s": wall,
            "idle_wall_s": self.idle_wall,
            "tokens_per_sec": total_new / max(wall, 1e-9),
            "decode_ticks": self.decode_ticks,
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p99_s": float(np.percentile(lat, 99)),
            "ttft_p50_s": (float(np.percentile(ttft, 50)) if ttft.size
                           else 0.0),
            "ttft_p99_s": (float(np.percentile(ttft, 99)) if ttft.size
                           else 0.0),
            "queue_wait_p50_s": float(np.percentile(wait, 50)),
            "queue_wait_p99_s": float(np.percentile(wait, 99)),
            **counters,
        }
