"""Oneshot serving: one fixed batch, synchronous prefill, lockstep decode.

This is the original ``repro.launch.serve`` driver factored into a library
so it can serve two roles:

* the **equivalence reference** for the continuous engine — for a single
  greedy request on a fixed seed the engine must reproduce these tokens
  bit-for-bit (tests/test_serve_engine.py), and
* the **baseline** for ``benchmarks/serve_throughput.py`` — every request
  is padded to the batch-max prompt length and decoded to the batch-max
  generation length, which is exactly the throughput collapse continuous
  batching exists to fix.

Sampling note: the lockstep driver keeps its legacy *shared* sampling key
(one fold per decode step, same key for every row).  The continuous engine
uses the per-slot, per-position schedule in ``repro.serve.engine`` instead;
see docs/SERVING.md for why the shared key is wrong under multi-tenancy.

A third role — the engine's degraded-mode *fallback* after repeated
slot-pool faults — lives in ``repro.runtime.supervisor.drain_with_oneshot``
rather than here: the drain reuses this driver's ``build_serve_setup``
device functions but samples with the engine's ``(request_id, position)``
key schedule, so drained tokens stay bit-identical to a fault-free
continuous run (which the legacy shared key above could not provide).
"""
from __future__ import annotations

import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import build_serve_setup


def build_oneshot_fns(model, run, mesh, batch: int, cache_len: int,
                      kv_fmt: str = "none") -> Tuple:
    """Jit the (prefill, decode) pair for a fixed batch/cache geometry.

    ``kv_fmt`` selects the KV-cache storage format (quantized caches use
    the same deterministic per-row quantization as the continuous engine,
    so the two stay token-identical at matching formats).
    """
    setup = build_serve_setup(model, run, mesh, batch, cache_len,
                              kv_fmt=kv_fmt)
    return jax.jit(setup.prefill_fn), jax.jit(setup.decode_fn)


def oneshot_generate(prefill, decode, params, batch: dict, gen: int, *,
                     temperature: float = 0.0,
                     base_key: Optional[jax.Array] = None):
    """Run batched prefill then ``gen - 1`` lockstep decode steps.

    Returns ``(tokens, timings)`` where ``tokens`` is the (B, gen) int32
    array of generated ids (position 0 comes from the prefill logits) and
    ``timings`` has ``prefill_s`` / ``decode_s`` wall times.
    """
    if base_key is None:
        base_key = jax.random.PRNGKey(0)
    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    def pick(logits, i):
        if temperature > 0:
            k = jax.random.fold_in(base_key, 100 + i)
            return jax.random.categorical(
                k, logits / temperature).astype(jnp.int32)
        return jnp.argmax(logits, -1).astype(jnp.int32)

    # legacy behavior preserved: the prefill token is always greedy; only
    # the decode-loop tokens are temperature-sampled (with the shared key)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    generated = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = pick(logits, i)
        generated.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    return (np.stack(generated, axis=1),
            {"prefill_s": t_prefill, "decode_s": t_decode})
