"""Slot pool: host-side bookkeeping for the fixed-size decode batch.

The continuous-batching engine (``repro.serve.engine``) allocates one
``max_slots x max_seq`` KV cache when it starts and never reallocates; a
*slot* is one row of that cache.  This module owns the host-side state of
the pool — which slots are free, which request occupies each busy slot, and
how many tokens each occupant may still generate — while the device-side
state (the KV tensors and the per-slot position vector) lives in the
engine's cache pytree.

Slot lifecycle (documented in docs/SERVING.md):

    FREE -> (admit: prefill writes the prompt KV) -> ACTIVE
         -> (retire: budget exhausted / EOS / cache full) -> FREE

A retired slot is reusable immediately: the next admission's prefill
overwrites cache rows ``[0, prompt_len)`` and every read is masked by the
slot's position, so stale KV from the previous occupant is never attended.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp


@dataclasses.dataclass
class SlotState:
    """Host-side record of one occupied slot."""

    request_id: int
    remaining: int          # generation budget left (tokens)
    prompt_len: int


class SlotPool:
    """Free-list allocator over the ``n_slots`` rows of the slot cache.

    Purely host-side and O(1) per operation; the engine consults it every
    tick to decide admission and retirement.  ``admissions`` counts total
    acquires per slot so tests can assert slots are actually reused.
    """

    def __init__(self, n_slots: int):
        """Create a pool with all ``n_slots`` slots free."""
        if n_slots < 1:
            raise ValueError("SlotPool needs at least one slot")
        self.n_slots = n_slots
        self._free: List[int] = list(range(n_slots - 1, -1, -1))
        self._busy: Dict[int, SlotState] = {}
        self.admissions = [0] * n_slots

    @property
    def n_free(self) -> int:
        """Number of currently free slots."""
        return len(self._free)

    @property
    def n_active(self) -> int:
        """Number of currently occupied slots."""
        return len(self._busy)

    def state(self, slot: int) -> SlotState:
        """Return the occupant record of a busy ``slot``."""
        return self._busy[slot]

    def active_slots(self) -> List[int]:
        """Occupied slot indices in ascending order."""
        return sorted(self._busy)

    def acquire(self, request_id: int, prompt_len: int,
                budget: int) -> Optional[int]:
        """Claim a free slot for ``request_id``; None when the pool is full."""
        if not self._free:
            return None
        slot = self._free.pop()
        self._busy[slot] = SlotState(request_id=request_id,
                                     remaining=budget,
                                     prompt_len=prompt_len)
        self.admissions[slot] += 1
        return slot

    def release(self, slot: int) -> SlotState:
        """Retire ``slot`` back to the free list and return its record."""
        state = self._busy.pop(slot)
        self._free.append(slot)
        return state


def init_slot_cache(model, n_slots: int, max_seq: int,
                    kv_fmt: str = "none"):
    """Materialize the zero-filled slot cache pytree for ``model``.

    Shapes come from the model's ``slot_cache_spec`` hook (for the dense
    transformer: k/v of shape (L, n_slots, KV, max_seq, hd) plus a
    (n_slots,) int32 position vector; quantized ``kv_fmt`` swaps k/v for
    code arrays and adds per-(slot, token, kv-head) scale arrays).  Zero
    initialization matters twice over: masked attention over a zero-padded
    cache is bit-identical to attention over a shorter cache, and for
    quantized formats a ZERO SCALE dequantizes every code to exactly 0 —
    the same invariant ``ContinuousEngine._retire`` restores when a slot
    is released, so a refilled slot can never dequantize a predecessor's
    rows against stale scales (docs/SERVING.md).
    """
    if model.slot_cache_spec is None:
        raise ValueError(
            f"model family {model.config.family!r} does not implement "
            "slot-pool decoding (decode_slots/slot_cache_spec)")
    if kv_fmt not in model.kv_formats:
        raise ValueError(
            f"model family {model.config.family!r} does not support "
            f"kv_fmt={kv_fmt!r} (supported: {model.kv_formats})")
    kw = {} if kv_fmt == "none" else {"kv_fmt": kv_fmt}
    spec = model.slot_cache_spec(n_slots, max_seq, **kw)
    return {name: jnp.zeros(sds.shape, sds.dtype)
            for name, sds in spec.items()}
