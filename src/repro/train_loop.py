"""Trainer: the full DPQuant training loop (paper Fig. 2 pipeline).

Per epoch:
  1. (every ``analysis_interval`` epochs) COMPUTELOSSIMPACT on Poisson-
     sampled probe batches — charges one "analysis" SGM step;
  2. SELECTTARGETS -> this epoch's quantized-layer flags;
  3. ``steps_per_epoch`` DP-SGD/DP-Adam steps on Poisson-sampled batches —
     each charges one "train" SGM step;
  4. optional eval + checkpoint (params, opt, accountant, scheduler, sampler).

Two epoch executors (``RunConfig.epoch_executor``):

  * ``"scan"`` (default) — the epoch's Poisson batches are pre-drawn,
    stacked, and the whole epoch runs as ONE compiled ``jax.lax.scan``
    program with donated params/opt buffers.  Invariant: the host
    synchronizes with the device **once per epoch** (reading the stacked
    per-step metrics); the RDP accountant is charged once with
    ``steps=steps_per_epoch``.  The quantization flags are fixed for the
    epoch (paper Fig. 2), so they ride along as a broadcast operand.
  * ``"loop"`` — the legacy per-step python loop (one dispatch + one host
    sync + one accountant charge per step).  Kept as a fallback and as the
    reference for the scan/loop equivalence test.

Both executors draw identical sample indices, per-step seeds, and learning
rates from the same ``RunConfig.seed``, and the accountant merges
consecutive identical SGM events, so they produce identical params,
optimizer state, and epsilon on a fixed seed.

Also supports mode="pls" / mode="static" (ablations / baselines) and
dp.enabled=False (the non-private comparison in paper Fig. 1a).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import RunConfig
from repro.core.scheduler import DPQuantScheduler
from repro.checkpoint.manager import CheckpointManager
from repro.data.poisson import PoissonSampler
from repro.dp.accountant import RDPAccountant
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_epoch_fn, build_train_setup
from repro.models.registry import Model, build_model
from repro.optim.schedule import make_schedule
from repro.runtime.preemption import Preempted, PreemptionHandler


@dataclasses.dataclass
class EpochStats:
    epoch: int
    loss: float
    eps: float
    analysis_eps_fraction: float
    quantized_layers: int
    accuracy: Optional[float] = None
    wall_s: float = 0.0


class Trainer:
    def __init__(self, run: RunConfig, dataset, *, mode: str = "dpquant",
                 eval_dataset=None, mesh=None, checkpoint_dir: str = None,
                 group_size: int = 1, eval_fn: Callable = None,
                 preemption: Optional[PreemptionHandler] = None):
        self.run = run
        self.dataset = dataset
        self.eval_dataset = eval_dataset
        self.eval_fn = eval_fn
        self.mode = mode
        # Fail fast on backend knobs: the dispatch happens at trace time
        # deep inside the jitted step, where a typo'd backend name would
        # surface as an opaque tracer error.  Both epoch executors run the
        # same step_fn, so scan/loop are interchangeable on any backend.
        from repro.quant.backend import resolve_backend
        resolve_backend(run.quant.backend)
        if run.dp.clip_backend not in ("ref", "fused"):
            raise ValueError(f"dp.clip_backend must be 'ref' or 'fused', "
                             f"got {run.dp.clip_backend!r}")
        self.model: Model = build_model(run.model, run.quant)
        # grad_mode validation (incl. ghost-hook support for the family)
        # happens in build_train_setup below, before any tracing
        self.mesh = mesh or make_host_mesh()
        self.setup = build_train_setup(self.model, run, self.mesh)
        self.step_fn = jax.jit(self.setup.step_fn,
                               in_shardings=self.setup.in_shardings,
                               out_shardings=self.setup.out_shardings)
        if run.epoch_executor not in ("scan", "loop"):
            raise ValueError(
                f"epoch_executor must be 'scan' or 'loop', "
                f"got {run.epoch_executor!r}")
        self.epoch_fn = (build_epoch_fn(self.setup, unroll=run.epoch_unroll)
                         if run.epoch_executor == "scan" else None)
        self.schedule = make_schedule(run.optim, run.steps)
        self.sampler = PoissonSampler(dataset.n, run.global_batch,
                                      seed=run.seed)
        self._probe_rng = np.random.RandomState(run.seed + 777)
        self.accountant = RDPAccountant()
        self.scheduler = DPQuantScheduler(
            n_layers=run.model.policy_len(), dp=run.dp, mode=mode,
            group_size=group_size, seed=run.seed)
        self.params = self.model.init(jax.random.PRNGKey(run.seed))
        self.opt_state = self.setup.opt_init_fn(self.params)
        self.step = 0
        self.history: List[EpochStats] = []
        self.ckpt = (CheckpointManager(checkpoint_dir)
                     if checkpoint_dir else None)
        self.preemption = preemption
        # epoch cursor: train(n) runs n epochs starting here; restore sets
        # it past the checkpointed epoch (or *at* it for mid-epoch resume)
        self._next_epoch = 0
        # mid-epoch resume record ({"epoch", "epoch_step", "epoch_losses"})
        # set by restore_latest when the checkpoint was a preemption save
        self._mid_epoch: Optional[dict] = None

    # ------------------------------------------------------------------ #
    def _probe_step(self, params, opt_state, batch, seed, flags):
        lr = self.schedule(self.step)
        return self.step_fn(params, opt_state, batch, seed, flags,
                            jnp.float32(lr))

    def _sample_batch(self) -> dict:
        return self.dataset.get(self.sampler.sample())

    # ------------------------------------------------------------------ #
    def train_epoch(self, epoch: int) -> EpochStats:
        t0 = time.time()
        run = self.run
        resume = None
        if self._mid_epoch is not None:
            if self._mid_epoch["epoch"] != epoch:
                raise RuntimeError(
                    f"mid-epoch checkpoint is for epoch "
                    f"{self._mid_epoch['epoch']}, cannot run epoch {epoch}")
            resume = self._mid_epoch
            self._mid_epoch = None
        if resume is None:
            # ---- Algorithm 1 (analysis) ----
            if self.mode == "dpquant":
                nb = min(run.dp.analysis_batch_size, run.global_batch)
                nb = max(run.dp.microbatch_size, nb)
                probe_batches = [self.dataset.get(self._probe_rng.randint(
                    0, self.dataset.n, nb))
                    for _ in range(run.dp.analysis_reps)]
                self.scheduler.maybe_analyze(
                    probe_step=self._probe_step, params=self.params,
                    opt_state=self.opt_state, batches=probe_batches,
                    sample_rate=min(1.0, nb / self.dataset.n),
                    accountant=self.accountant,
                    epoch=epoch, seed=run.seed * 1000 + epoch)
            # ---- Algorithm 2 (selection) ----
            policy = self.scheduler.select(epoch)
        else:
            # mid-epoch resume: analysis + selection already ran before the
            # preemption and their RNG draws / accountant charges are in
            # the restored state — re-running either would double-consume
            # the probe and scheduler streams.  The restored scheduler
            # still holds this epoch's policy.
            policy = self.scheduler.current
        flags = policy.flags()

        # ---- DP-SGD steps ----
        start = resume["epoch_step"] if resume else 0
        prior = resume["epoch_losses"] if resume else []
        if run.epoch_executor == "scan":
            losses = self._train_steps_scan(flags, epoch, start, prior)
        else:
            losses = self._train_steps_loop(flags, epoch, start, prior)

        eps, _ = (self.accountant.get_epsilon(run.dp.delta)
                  if run.dp.enabled else (0.0, 0))
        frac = (self.accountant.analysis_fraction(run.dp.delta)
                if run.dp.enabled and self.mode == "dpquant" else 0.0)
        acc = self.evaluate() if self.eval_dataset is not None else None
        stats = EpochStats(epoch=epoch, loss=float(np.mean(losses)),
                           eps=eps, analysis_eps_fraction=frac,
                           quantized_layers=len(policy), accuracy=acc,
                           wall_s=time.time() - t0)
        self.history.append(stats)
        if self.ckpt is not None:
            self.save(epoch)
        return stats

    def _maybe_preempt(self, epoch: int, epoch_step: int,
                       losses: List[float]) -> None:
        """Step-boundary preemption poll (both executors call this).

        When the handler fires, a *mid-epoch* checkpoint is written —
        params, opt state, accountant history, scheduler EMA/policy,
        sampler + probe RNG stream positions, and the epoch cursor — and
        :class:`Preempted` is raised.  The accountant is already exact at
        every step boundary (the loop executor charges per step; the scan
        executor charges per chunk, and consecutive identical SGM events
        merge), so the saved epsilon equals the uninterrupted run's at the
        same global step.
        """
        if self.preemption is None or not self.preemption.should_preempt(
                self.step):
            return
        if self.ckpt is not None:
            self.save(epoch, epoch_step=epoch_step, epoch_losses=losses,
                      mid_epoch=True)
            self.ckpt.wait()
        raise Preempted(self.step)

    def _train_steps_loop(self, flags, epoch: int, start: int = 0,
                          prior: List[float] = ()) -> List[float]:
        """Legacy executor: one dispatch + host sync + charge per step."""
        run = self.run
        losses = list(prior)
        for es in range(start, run.steps_per_epoch):
            batch = self._sample_batch()
            lr = self.schedule(self.step)
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch,
                jnp.uint32(self.step + run.seed), flags, jnp.float32(lr))
            losses.append(float(metrics["loss"]))
            if run.dp.enabled:
                self.accountant.step(
                    noise_multiplier=run.dp.noise_multiplier,
                    sample_rate=self.sampler.q, steps=1, label="train")
            self.step += 1
            self._maybe_preempt(epoch, es + 1, losses)
        return losses

    def _train_steps_scan(self, flags, epoch: int, start: int = 0,
                          prior: List[float] = ()) -> List[float]:
        """Scan executor: the epoch (in chunks of ``epoch_chunk`` steps, or
        whole) runs as one compiled program; the host syncs once per chunk
        and the accountant is charged once per chunk — consecutive
        identical SGM events merge, so the history is identical to a
        single per-epoch charge while staying exact at every chunk
        boundary (where preemption may checkpoint)."""
        run = self.run
        steps = run.steps_per_epoch
        chunk = run.epoch_chunk if run.epoch_chunk > 0 else steps
        losses: List[float] = list(prior)
        done = start
        while done < steps:
            k = min(chunk, steps - done)
            idx = self.sampler.sample_epoch(k)
            flat = self.dataset.get(idx.reshape(-1))
            batches = jax.tree_util.tree_map(
                lambda x: x.reshape((k, -1) + x.shape[1:]), flat)
            seeds = jnp.asarray(
                np.arange(self.step, self.step + k) + run.seed, jnp.uint32)
            lrs = jnp.asarray([self.schedule(self.step + i) for i in range(k)],
                              jnp.float32)
            self.params, self.opt_state, metrics = self.epoch_fn(
                self.params, self.opt_state, batches, seeds, flags, lrs)
            losses.extend(float(v) for v in np.asarray(metrics["loss"]))
            self.step += k
            done += k
            if run.dp.enabled:
                self.accountant.step(
                    noise_multiplier=run.dp.noise_multiplier,
                    sample_rate=self.sampler.q, steps=k, label="train")
            self._maybe_preempt(epoch, done, losses)
        return losses

    def train(self, epochs: int, *, eps_budget: Optional[float] = None,
              verbose: bool = False) -> List[EpochStats]:
        """Train ``epochs`` more epochs from the current epoch cursor.

        A fresh trainer starts at epoch 0; after ``restore_latest`` the
        cursor sits past the last completed epoch (or *at* the preempted
        epoch for a mid-epoch checkpoint, which is finished first).
        """
        start = self._next_epoch
        for e in range(start, start + epochs):
            stats = self.train_epoch(e)
            self._next_epoch = e + 1
            if verbose:
                print(f"epoch {e}: loss={stats.loss:.4f} eps={stats.eps:.3f} "
                      f"k={stats.quantized_layers} acc={stats.accuracy}")
            if eps_budget is not None and stats.eps >= eps_budget:
                break  # paper: truncate training at the privacy budget
        return self.history

    # ------------------------------------------------------------------ #
    def evaluate(self, n: int = 512) -> float:
        if self.eval_fn is not None:
            return self.eval_fn(self.params)
        idx = np.arange(min(n, self.eval_dataset.n))
        batch = self.eval_dataset.get(idx)
        if "label" not in batch:
            return float("nan")
        flags = jnp.zeros((self.run.model.policy_len(),), jnp.float32)
        preds = self._predict(batch, flags)
        return float((preds == np.asarray(batch["label"])).mean())

    def _predict(self, batch, flags):
        from repro.models import resnet as rn, densenet as dn, bert as bt
        cfg, quant = self.run.model, self.run.quant
        if cfg.family == "resnet":
            logits = rn.forward(self.params, batch["image"], flags, cfg, quant)
        elif cfg.family == "densenet":
            logits = dn.forward(self.params, batch["image"], flags, cfg, quant)
        elif cfg.family == "bert":
            h = bt.forward(self.params, batch["tokens"], flags, cfg, quant)
            logits = (h[:, 0].astype(jnp.float32) @ self.params["cls_w"]
                      + self.params["cls_b"])
        else:
            raise ValueError(f"no predict for family {cfg.family}")
        return np.asarray(jnp.argmax(logits, -1))

    # ------------------------------------------------------------------ #
    def save(self, epoch: int, *, epoch_step: int = 0,
             epoch_losses: List[float] = (), mid_epoch: bool = False) -> None:
        """Checkpoint everything a bit-identical resume needs.

        Besides params/opt, the aux payload carries the accountant
        history, scheduler EMA + current policy, sampler RNG cursor, the
        probe RNG stream position (analysis batch draws), and — for
        preemption saves (``mid_epoch``) — the epoch step index and the
        partial per-step losses so the finished epoch's stats match the
        uninterrupted run's.
        """
        aux = {
            "accountant": self.accountant.state_dict(),
            "scheduler": self.scheduler.state_dict(),
            "sampler": self.sampler.state_dict(),
            "probe_rng": self._probe_rng.get_state(),
            "history": [dataclasses.asdict(s) for s in self.history],
            "step": self.step,
            "epoch": epoch,
            "mid_epoch": bool(mid_epoch),
            "epoch_step": int(epoch_step),
            "epoch_losses": [float(x) for x in epoch_losses],
        }
        self.ckpt.save(self.step, {"params": self.params,
                                   "opt": self.opt_state}, aux)

    def restore_latest(self) -> Optional[int]:
        if self.ckpt is None:
            return None
        res = self.ckpt.restore_latest({"params": self.params,
                                        "opt": self.opt_state})
        if res is None:
            return None
        _, tree, aux = res
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.accountant = RDPAccountant.from_state_dict(aux["accountant"])
        self.scheduler.load_state_dict(aux["scheduler"])
        self.sampler.load_state_dict(aux["sampler"])
        if "probe_rng" in aux:
            self._probe_rng.set_state(aux["probe_rng"])
        self.history = [EpochStats(**d) for d in aux.get("history", [])]
        self.step = aux["step"]
        if aux.get("mid_epoch"):
            # preemption save: re-enter the interrupted epoch, skipping
            # analysis/selection and the already-run steps (train_epoch)
            self._mid_epoch = {"epoch": aux["epoch"],
                               "epoch_step": aux["epoch_step"],
                               "epoch_losses": list(aux["epoch_losses"])}
            self._next_epoch = aux["epoch"]
        else:
            self._mid_epoch = None
            self._next_epoch = aux["epoch"] + 1
        return aux["epoch"]
