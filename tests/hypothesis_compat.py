"""``hypothesis`` shim: real property testing when installed, fixed grids not.

``hypothesis`` is a dev extra (see pyproject.toml).  In minimal containers it
may be absent; property tests then degenerate to a deterministic grid over
each strategy's bounds so the suite still runs (and still exercises the
property at the extremes) instead of failing at collection.
"""
from __future__ import annotations

import itertools

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    class _GridStrategy:
        def __init__(self, values):
            self.values = list(values)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            span = max_value - min_value
            picks = {min_value, max_value,
                     min_value + span // 3, min_value + (2 * span) // 3}
            return _GridStrategy(sorted(picks))

        @staticmethod
        def floats(min_value, max_value):
            if min_value > 0:
                vals = np.geomspace(min_value, max_value, 4)
            else:
                vals = np.linspace(min_value, max_value, 4)
            return _GridStrategy(float(v) for v in vals)

    st = _Strategies()

    def settings(**_kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            cases = list(itertools.product(*(s.values for s in strategies)))

            def wrapper():
                for case in cases:
                    fn(*case)
            # NOT functools.wraps: pytest must see the zero-arg signature,
            # not the wrapped (q, sigma, ...) one (it would hunt fixtures).
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
