"""RDP accountant vs a numerical-integration oracle + properties."""
import math

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.dp.accountant import (DEFAULT_ORDERS, RDPAccountant,
                                 compute_rdp_sgm, rdp_to_eps)


def rdp_oracle(q, sigma, alpha, n=800_001, span=40.0):
    x = np.linspace(-span, span, n)
    log_mu0 = -x ** 2 / (2 * sigma ** 2) - math.log(sigma * math.sqrt(2 * math.pi))
    log_mu1 = -(x - 1) ** 2 / (2 * sigma ** 2) - math.log(
        sigma * math.sqrt(2 * math.pi))
    log_mix = np.logaddexp(math.log1p(-q) + log_mu0, math.log(q) + log_mu1)
    integrand = np.exp(log_mu0 + alpha * (log_mix - log_mu0))
    return math.log(np.trapezoid(integrand, x)) / (alpha - 1)


@pytest.mark.parametrize("q,sigma,alpha", [
    (0.01, 1.0, 2.0), (0.01, 1.0, 8.0), (0.01, 1.0, 2.5),
    (0.05, 0.8, 3.5), (0.1, 1.5, 1.25), (0.02, 0.5, 4.0),
    (0.001, 2.0, 32.0), (0.5, 1.0, 6.0), (0.2, 0.7, 10.5),
])
def test_rdp_matches_numerical_oracle(q, sigma, alpha):
    got = compute_rdp_sgm(q, sigma, alpha)
    want = rdp_oracle(q, sigma, alpha)
    assert abs(got - want) / max(abs(want), 1e-12) < 1e-4


def test_q1_reduces_to_gaussian_mechanism():
    for sigma in (0.5, 1.0, 4.0):
        for alpha in (2.0, 8.0, 64.0):
            assert abs(compute_rdp_sgm(1.0, sigma, alpha)
                       - alpha / (2 * sigma ** 2)) < 1e-12


@settings(max_examples=30, deadline=None)
@given(st.floats(min_value=0.001, max_value=0.3),
       st.floats(min_value=0.5, max_value=4.0))
def test_eps_monotone_in_steps(q, sigma):
    a = RDPAccountant()
    a.step(noise_multiplier=sigma, sample_rate=q, steps=10)
    e1, _ = a.get_epsilon(1e-5)
    a.step(noise_multiplier=sigma, sample_rate=q, steps=90)
    e2, _ = a.get_epsilon(1e-5)
    assert e2 >= e1 >= 0


def test_eps_decreasing_in_sigma():
    eps = []
    for sigma in (0.6, 1.0, 2.0, 4.0):
        a = RDPAccountant()
        a.step(noise_multiplier=sigma, sample_rate=0.01, steps=1000)
        eps.append(a.get_epsilon(1e-5)[0])
    assert all(e1 > e2 for e1, e2 in zip(eps, eps[1:])), eps


def test_mnist_reference_point():
    """sigma=1.1, q=256/60000, 30 epochs — classic DP-SGD tutorial setting;
    eps should land near ~1.8 (TF-privacy reports ~1.79 at delta=1e-5)."""
    a = RDPAccountant()
    a.step(noise_multiplier=1.1, sample_rate=256 / 60_000,
           steps=int(60_000 / 256 * 30))
    eps, _ = a.get_epsilon(1e-5)
    assert 1.5 < eps < 2.2, eps


def test_analysis_composition_and_fraction():
    a = RDPAccountant()
    a.step(noise_multiplier=1.0, sample_rate=0.02, steps=2000, label="train")
    e_train, _ = a.get_epsilon(1e-5)
    a.step(noise_multiplier=0.5, sample_rate=0.02, steps=10, label="analysis")
    e_both, _ = a.get_epsilon(1e-5)
    assert e_both > e_train
    frac = a.analysis_fraction(1e-5)
    assert 0.0 < frac < 1.0


def test_state_roundtrip():
    a = RDPAccountant()
    a.step(noise_multiplier=1.2, sample_rate=0.01, steps=55)
    a.step(noise_multiplier=0.5, sample_rate=0.03, steps=2, label="analysis")
    b = RDPAccountant.from_state_dict(a.state_dict())
    assert a.get_epsilon(1e-5) == b.get_epsilon(1e-5)


def test_invalid_inputs():
    a = RDPAccountant()
    with pytest.raises(ValueError):
        a.step(noise_multiplier=1.0, sample_rate=1.5)
    with pytest.raises(ValueError):
        a.step(noise_multiplier=-1.0, sample_rate=0.5)
