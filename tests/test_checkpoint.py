"""Checkpoint: roundtrip, CRC, retention, torn writes, accountant aux."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint import serialization
from repro.dp.accountant import RDPAccountant


def make_tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,))},
            "opt": (jnp.zeros((3, 4)),)}


def test_roundtrip(tmp_path):
    tree = make_tree()
    serialization.save(tmp_path / "c.ckpt", tree, {"step": 7})
    restored, aux = serialization.restore(tmp_path / "c.ckpt", tree)
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert aux["step"] == 7


def test_crc_detects_corruption(tmp_path):
    tree = make_tree()
    serialization.save(tmp_path / "c.ckpt", tree)
    payload = (tmp_path / "c.ckpt" / "arrays.npz").read_bytes()
    (tmp_path / "c.ckpt" / "arrays.npz").write_bytes(
        payload[:-8] + b"corrupt!")
    with pytest.raises(IOError):
        serialization.restore(tmp_path / "c.ckpt", tree)


def test_manager_retention_and_latest(tmp_path):
    m = CheckpointManager(tmp_path, keep=2, async_write=False)
    tree = make_tree()
    for step in (1, 2, 3, 4):
        t = {"params": {"w": jnp.full((3, 4), float(step)),
                        "b": jnp.ones((4,))},
             "opt": (jnp.zeros((3, 4)),)}
        m.save(step, t, {"epoch": step})
    assert m.steps() == [3, 4]
    step, restored, aux = m.restore_latest(tree)
    assert step == 4
    assert aux["epoch"] == 4
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.full((3, 4), 4.0))


def test_manager_skips_corrupted_latest(tmp_path):
    m = CheckpointManager(tmp_path, keep=5, async_write=False)
    tree = make_tree()
    m.save(1, tree, {"epoch": 1})
    m.save(2, tree, {"epoch": 2})
    npz = tmp_path / "step_0000000002.ckpt" / "arrays.npz"
    npz.write_bytes(b"garbage")
    step, _, aux = m.restore_latest(tree)
    assert step == 1                       # fell back past the corrupted one


def test_accountant_in_aux_roundtrip(tmp_path):
    m = CheckpointManager(tmp_path, async_write=False)
    acc = RDPAccountant()
    acc.step(noise_multiplier=1.0, sample_rate=0.01, steps=42)
    acc.step(noise_multiplier=0.5, sample_rate=0.02, steps=1,
             label="analysis")
    m.save(10, make_tree(), {"accountant": acc.state_dict()})
    _, _, aux = m.restore_latest(make_tree())
    acc2 = RDPAccountant.from_state_dict(aux["accountant"])
    assert acc2.get_epsilon(1e-5) == acc.get_epsilon(1e-5)
    assert acc2.history[1].label == "analysis"


def test_torn_write_never_shadows_previous_checkpoint(tmp_path):
    """A writer killed mid-save leaves only a ``step_*.tmp`` staging dir
    (the destination appears atomically via os.replace): it must not be
    listed as a step, restore must fall back to the previous valid
    checkpoint, and a restarted manager sweeps the orphan."""
    m = CheckpointManager(tmp_path, async_write=False)
    tree = make_tree()
    m.save(1, tree, {"epoch": 1})
    torn = tmp_path / "step_0000000002.tmp"
    torn.mkdir()
    (torn / "arrays.npz").write_bytes(b"half-written garbage")
    assert m.steps() == [1]
    step, _, aux = m.restore_latest(tree)
    assert step == 1 and aux["epoch"] == 1
    # restart: a fresh manager on the same dir removes the staging orphan
    CheckpointManager(tmp_path, async_write=False)
    assert not torn.exists()
    assert m.steps() == [1]


def test_half_built_destination_is_ignored(tmp_path):
    """A destination dir missing meta.json (torn pre-atomic-write layout)
    is not a valid step and never masks older checkpoints."""
    m = CheckpointManager(tmp_path, async_write=False)
    tree = make_tree()
    m.save(1, tree, {"epoch": 1})
    bad = tmp_path / "step_0000000002.ckpt"
    bad.mkdir()
    (bad / "arrays.npz").write_bytes(b"junk")
    assert m.steps() == [1]
    step, _, _ = m.restore_latest(tree)
    assert step == 1


def test_failed_save_cleans_staging_dir(tmp_path):
    """An exception mid-serialization removes the .tmp dir and never
    creates the destination."""
    path = tmp_path / "c.ckpt"
    with pytest.raises(TypeError):
        serialization.save(path, make_tree(), {"bad": object()})
    assert not path.exists()
    assert not path.with_suffix(".tmp").exists()


def test_async_write(tmp_path):
    m = CheckpointManager(tmp_path, async_write=True)
    m.save(5, make_tree(), {})
    m.wait()
    assert m.steps() == [5]
