"""Compressed cross-pod gradient reduction (multi-device via subprocess —
the main test process must keep the default 1-CPU-device view)."""
import subprocess
import sys
import textwrap


def test_compressed_psum_matches_exact():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.parallel.collectives import compressed_psum_pods
        from repro.launch.mesh import make_compat_mesh

        mesh = make_compat_mesh((2, 2, 2), ("pod", "data", "model"))
        key = jax.random.PRNGKey(0)
        # per-pod partials: (pods, 64, 32), model-sharded on last dim
        parts = jax.random.normal(key, (2, 64, 32), jnp.float32)
        parts = jax.device_put(
            parts, NamedSharding(mesh, P("pod", None, "model")))
        specs = {"g": P(None, "model")}
        out = compressed_psum_pods({"g": parts}, mesh, jnp.uint32(3), specs)
        exact = np.asarray(parts).sum(axis=0)
        got = np.asarray(out["g"])
        assert got.shape == exact.shape, got.shape
        rel = np.linalg.norm(got - exact) / np.linalg.norm(exact)
        # int8 stochastic quantization: small but nonzero error
        assert rel < 0.02, rel
        assert rel > 0, rel
        print("OK rel=%.5f" % rel)
    """)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300, cwd=".")
    assert res.returncode == 0, res.stderr + res.stdout
    assert "OK" in res.stdout


def test_multidevice_dp_step_parity():
    """The same DP train step on 1 device vs an 8-device (2,4) mesh must
    produce identical losses (SPMD-consistent noise + clipping)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.config import RunConfig, DPConfig, OptimConfig, QuantConfig
        from repro.configs import get_smoke_config
        from repro.launch.steps import build_train_setup
        from repro.models.registry import build_model
        from repro.launch.mesh import make_compat_mesh

        cfg = get_smoke_config("gemma-7b")
        model = build_model(cfg, QuantConfig(fmt="none"))
        run = RunConfig(model=cfg, quant=QuantConfig(fmt="none"),
                        dp=DPConfig(enabled=True, microbatch_size=2),
                        optim=OptimConfig(name="sgd", lr=0.1),
                        global_batch=8, seq_len=16)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (8, 16), 0, cfg.vocab_size)}
        flags = jnp.zeros((cfg.n_layers,), jnp.float32)
        losses = {}
        for shape, names in [((1, 1), ("data", "model")),
                             ((4, 2), ("data", "model"))]:
            mesh = make_compat_mesh(shape, names)
            setup = build_train_setup(model, run, mesh)
            step = jax.jit(setup.step_fn, in_shardings=setup.in_shardings,
                           out_shardings=setup.out_shardings)
            opt = setup.opt_init_fn(params)
            p2, o2, m = step(params, opt, batch, jnp.uint32(5), flags,
                             jnp.float32(0.1))
            losses[shape] = float(m["loss"])
        vals = list(losses.values())
        assert abs(vals[0] - vals[1]) < 2e-3, losses
        print("OK", losses)
    """)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600, cwd=".")
    assert res.returncode == 0, res.stderr + res.stdout
    assert "OK" in res.stdout
