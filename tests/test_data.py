"""Synthetic data + Poisson sampler."""
import numpy as np

from repro.data.poisson import PoissonSampler
from repro.data.synthetic import ImageClassDataset, NLIDataset, TokenDataset


def test_image_dataset_deterministic():
    ds = ImageClassDataset(n=64, num_classes=5, image_size=8)
    a = ds.get(np.array([1, 2, 3]))
    b = ds.get(np.array([1, 2, 3]))
    np.testing.assert_array_equal(np.asarray(a["image"]),
                                  np.asarray(b["image"]))
    assert a["image"].shape == (3, 8, 8, 3)
    assert set(np.asarray(ds.labels)) <= set(range(5))


def test_token_dataset_bigram_structure():
    ds = TokenDataset(n=16, vocab=64, seq_len=32)
    batch = ds.get(np.arange(8))
    toks = np.asarray(batch["tokens"])
    assert toks.shape == (8, 32)
    assert toks.min() >= 0 and toks.max() < 64
    # the planted grammar: most transitions come from the successor table
    hits = 0
    for seq in toks:
        for t in range(1, len(seq)):
            if seq[t] in ds.successors[seq[t - 1]]:
                hits += 1
    assert hits / (8 * 31) > 0.5


def test_nli_dataset():
    ds = NLIDataset(n=32, vocab=100, seq_len=16)
    b = ds.get(np.arange(4))
    assert b["tokens"].shape == (4, 16)
    assert b["label"].shape == (4,)


def test_poisson_sampler_rate():
    s = PoissonSampler(dataset_size=10_000, batch_size=100, seed=0)
    sizes = []
    for _ in range(50):
        idx = s.sample()
        assert len(idx) == 100                 # padded/trimmed physical batch
        sizes.append(len(np.unique(idx)))
    assert abs(np.mean(sizes) - 100) < 15      # ~Poisson(100)


def test_poisson_sampler_state_roundtrip():
    s1 = PoissonSampler(1000, 10, seed=3)
    s1.sample()
    state = s1.state_dict()
    a = s1.sample()
    s2 = PoissonSampler(1000, 10, seed=99)
    s2.load_state_dict(state)
    b = s2.sample()
    np.testing.assert_array_equal(a, b)
