"""Docs cannot rot: the capability table in docs/QUANTIZATION.md must match
the quant/backend registry, and every markdown link must resolve."""
import importlib.util
import re
from pathlib import Path

from repro.quant import backend as qb

REPO = Path(__file__).resolve().parent.parent
QUANT_DOC = REPO / "docs" / "QUANTIZATION.md"


def _load_linkcheck():
    spec = importlib.util.spec_from_file_location(
        "check_docs_links", REPO / "scripts" / "check_docs_links.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def parse_doc_capability_table():
    """Parse the marker-delimited table into {op: {backend: (formats...)}}."""
    text = QUANT_DOC.read_text()
    m = re.search(r"<!-- capability-table:begin -->(.*?)"
                  r"<!-- capability-table:end -->", text, re.S)
    assert m, "capability-table markers missing from docs/QUANTIZATION.md"
    rows = [r for r in m.group(1).strip().splitlines() if r.startswith("|")]
    header = [c.strip().strip("`") for c in rows[0].strip("|").split("|")]
    assert header[0] == "op"
    backends = header[1:]
    table = {}
    for row in rows[2:]:                       # skip header + separator
        cells = [c.strip() for c in row.strip("|").split("|")]
        op = cells[0].strip("`")
        table[op] = {}
        for backend, cell in zip(backends, cells[1:]):
            fmts = tuple(sorted(f.strip().strip("`")
                                for f in cell.split(",") if f.strip()))
            table[op][backend] = fmts
    return table


def test_capability_table_in_docs_matches_registry():
    """The format×op×backend table documented in docs/QUANTIZATION.md is
    generated from capability_table(); any drift fails CI (docs job)."""
    doc = parse_doc_capability_table()
    code = qb.capability_table()
    assert set(doc) == set(code), (
        f"ops differ: doc={sorted(doc)} code={sorted(code)}")
    for op in code:
        assert set(doc[op]) == set(code[op]), (op, doc[op], code[op])
        for backend in code[op]:
            assert doc[op][backend] == code[op][backend], (
                f"docs/QUANTIZATION.md capability table is stale for "
                f"op={op!r} backend={backend!r}: doc lists "
                f"{doc[op][backend]}, registry has {code[op][backend]}")


def test_docs_pages_exist_and_are_linked_from_readme():
    readme = (REPO / "README.md").read_text()
    for page in ("ARCHITECTURE.md", "SERVING.md", "QUANTIZATION.md"):
        assert (REPO / "docs" / page).exists(), f"docs/{page} missing"
        assert f"docs/{page}" in readme, f"README does not link docs/{page}"


def test_markdown_links_resolve():
    """Same check the CI docs job runs via scripts/check_docs_links.py."""
    mod = _load_linkcheck()
    errors = []
    for f in mod.collect([str(REPO / "README.md"), str(REPO / "docs")]):
        errors += mod.check_file(f)
    assert not errors, "\n".join(errors)


def test_github_slugification():
    mod = _load_linkcheck()
    assert mod.github_slug("RNG stream contract") == "rng-stream-contract"
    assert mod.github_slug("Why continuous batching?") == \
        "why-continuous-batching"
    assert mod.github_slug("`quantize` (fake-quant)") == "quantize-fake-quant"
