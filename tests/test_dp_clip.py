"""Per-example clipping invariants + noising statistics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dp.clip import clip_by_global_norm, per_example_clipped_grad_sum
from repro.dp.noise import add_gaussian_noise


def quad_loss(params, ex, rng):
    del rng
    return 0.5 * jnp.sum((params["w"] * ex["x"] - ex["y"]) ** 2)


def make_batch(n=8, d=5, seed=0):
    key = jax.random.PRNGKey(seed)
    return {"x": jax.random.normal(key, (n, d)),
            "y": jax.random.normal(jax.random.fold_in(key, 1), (n, d))}


def test_clipped_sum_bounded():
    params = {"w": jnp.ones((5,)) * 2.0}
    batch = make_batch()
    C = 0.7
    g, metrics = per_example_clipped_grad_sum(
        quad_loss, params, batch, clip_norm=C, microbatch_size=4,
        rng=jax.random.PRNGKey(0))
    total = float(jnp.linalg.norm(g["w"]))
    assert total <= 8 * C + 1e-5          # triangle inequality bound


def test_microbatch_size_invariance():
    """The clipped-grad sum must not depend on how the batch is chunked."""
    params = {"w": jnp.ones((5,)) * 1.5}
    batch = make_batch()
    outs = []
    for mb in (1, 2, 4, 8):
        g, _ = per_example_clipped_grad_sum(
            quad_loss, params, batch, clip_norm=1.0, microbatch_size=mb,
            rng=jax.random.PRNGKey(0))
        outs.append(np.asarray(g["w"]))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5)


def test_matches_manual_per_example():
    params = {"w": jnp.arange(1.0, 6.0)}
    batch = make_batch(n=4)
    C = 0.5
    g, metrics = per_example_clipped_grad_sum(
        quad_loss, params, batch, clip_norm=C, microbatch_size=2,
        rng=jax.random.PRNGKey(0))
    manual = np.zeros(5)
    for i in range(4):
        ex = {k: v[i] for k, v in batch.items()}
        gi = np.asarray(jax.grad(quad_loss)(params, ex, None)["w"])
        norm = np.linalg.norm(gi)
        manual += gi * min(1.0, C / norm)
    np.testing.assert_allclose(np.asarray(g["w"]), manual, rtol=1e-5)
    assert metrics["clip_fraction"] >= 0.0


def test_partial_accum_non_divisible_mb_falls_back():
    """partial_accum_shards that do not divide the microbatch fall back to
    the plain (non-partial) accumulation and still produce identical sums."""
    params = {"w": jnp.ones((5,)) * 1.5}
    batch = make_batch()
    base, _ = per_example_clipped_grad_sum(
        quad_loss, params, batch, clip_norm=1.0, microbatch_size=4,
        rng=jax.random.PRNGKey(0))
    odd, _ = per_example_clipped_grad_sum(
        quad_loss, params, batch, clip_norm=1.0, microbatch_size=4,
        rng=jax.random.PRNGKey(0), partial_accum_shards=3)  # 4 % 3 != 0
    np.testing.assert_allclose(np.asarray(odd["w"]), np.asarray(base["w"]),
                               rtol=1e-6)
    # divisible shards keep one partial sum per shard -> same total
    div, _ = per_example_clipped_grad_sum(
        quad_loss, params, batch, clip_norm=1.0, microbatch_size=4,
        rng=jax.random.PRNGKey(0), partial_accum_shards=2)
    np.testing.assert_allclose(np.asarray(div["w"]), np.asarray(base["w"]),
                               rtol=1e-5)


def test_fused_clip_rejects_partial_accum():
    """The fused Pallas kernel sums the whole microbatch in-kernel and
    cannot keep per-shard partials — must be an explicit error."""
    params = {"w": jnp.ones((5,))}
    batch = make_batch()
    with pytest.raises(ValueError, match="partial_accum"):
        per_example_clipped_grad_sum(
            quad_loss, params, batch, clip_norm=1.0, microbatch_size=4,
            rng=jax.random.PRNGKey(0), clip_backend="fused",
            partial_accum_shards=2)


def test_clip_backend_validated():
    params = {"w": jnp.ones((5,))}
    batch = make_batch()
    with pytest.raises(ValueError, match="clip_backend"):
        per_example_clipped_grad_sum(
            quad_loss, params, batch, clip_norm=1.0, microbatch_size=4,
            rng=jax.random.PRNGKey(0), clip_backend="bogus")


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((3,)) * 10, "b": jnp.ones((2, 2)) * -10}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    from repro.dp.clip import global_norm
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0


def test_noise_statistics():
    zeros = {"w": jnp.zeros((20_000,))}
    C, sigma, B = 1.3, 2.0, 16
    noisy = add_gaussian_noise(zeros, clip_norm=C, noise_multiplier=sigma,
                               batch_size=B, rng=jax.random.PRNGKey(0))
    std = float(jnp.std(noisy["w"]))
    expected = sigma * C / B
    assert abs(std - expected) / expected < 0.05


def test_noise_deterministic_in_key():
    zeros = {"w": jnp.zeros((64,))}
    n1 = add_gaussian_noise(zeros, clip_norm=1, noise_multiplier=1,
                            batch_size=4, rng=jax.random.PRNGKey(5))
    n2 = add_gaussian_noise(zeros, clip_norm=1, noise_multiplier=1,
                            batch_size=4, rng=jax.random.PRNGKey(5))
    np.testing.assert_array_equal(np.asarray(n1["w"]), np.asarray(n2["w"]))
