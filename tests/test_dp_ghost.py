"""Ghost-norm two-pass DP gradient engine: parity with the vmap path.

The acceptance contract (docs/ARCHITECTURE.md "DP gradient modes"):
``grad_mode="ghost"`` must reproduce the vmap path's clipped grad sums,
per-example norms and clip metrics to fp32 tolerance — including with
stochastic ``luq_fp4`` quantization enabled (LUQ's per-tensor max scaling
is exactly positively-scale-invariant, and ghost mode quantizes batched
operands per example with the vmap path's hoisted draws).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (DPConfig, ModelConfig, OptimConfig, QuantConfig,
                          RunConfig)
from repro.dp.clip import per_example_clipped_grad_sum
from repro.dp.engine import make_dp_grad_fn, validate_grad_mode
from repro.dp.ghost import (ghost_clipped_grad_sum, ghost_per_example_norms,
                            per_example_state_bytes)
from repro.models.registry import build_model
from repro.quant.fake_quant import qconv2d


# --------------------------------------------------------------------------- #
# fixtures
# --------------------------------------------------------------------------- #
def lm_cfg(**kw):
    base = dict(name="ghost-lm", family="dense_lm", n_layers=2, d_model=32,
                n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=128,
                compute_dtype="float32", remat=True)
    base.update(kw)
    return ModelConfig(**base)


def resnet_cfg(**kw):
    base = dict(name="ghost-rn", family="resnet", resnet_blocks=(1, 1),
                num_classes=8, image_size=16, compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def densenet_cfg():
    return ModelConfig(name="ghost-dn", family="densenet",
                       densenet_blocks=(2, 2), growth_rate=8, num_classes=8,
                       image_size=16, compute_dtype="float32")


def make_batch(cfg, B, seed=1):
    if cfg.family == "dense_lm":
        return {"tokens": jax.random.randint(jax.random.PRNGKey(seed),
                                             (B, 16), 0, cfg.vocab_size)}
    s = cfg.image_size
    return {"image": jax.random.normal(jax.random.PRNGKey(seed),
                                       (B, s, s, cfg.in_channels)),
            "label": jax.random.randint(jax.random.PRNGKey(seed + 1),
                                        (B,), 0, cfg.num_classes)}


def both_paths(cfg, fmt, B=6, clip_norm=0.8, mb=None, use_aux=True,
               ghost_microbatch=0):
    """(vmap_out, ghost_out, vmap_norms, ghost_norms) for one config.

    ``use_aux=True`` runs ghost with the model's GhostAux hooks when the
    family provides them (full hook coverage — the engine default);
    ``use_aux=False`` forces the vmapped norm-only fallback for the
    non-op-hooked leaves (the pre-aux formulation, still supported).
    """
    model = build_model(cfg, QuantConfig(fmt=fmt))
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B)
    qflags = jnp.ones((cfg.policy_len(),), jnp.float32)

    def loss_one(p, ex, r):
        b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
        return model.loss_fn(p, b1, r, qflags)

    def pel(p, b, r):
        return model.per_example_loss(p, b, r, qflags)

    aux = (model.ghost_aux(qflags)
           if use_aux and model.ghost_aux is not None else None)
    rng = jax.random.PRNGKey(42)
    vm = jax.jit(lambda p, b: per_example_clipped_grad_sum(
        loss_one, p, b, clip_norm=clip_norm, microbatch_size=mb or B,
        rng=rng))(params, batch)
    gh = jax.jit(lambda p, b: ghost_clipped_grad_sum(
        loss_one, pel, p, b, clip_norm=clip_norm, rng=rng,
        hooked_mask=model.ghost_mask(p), aux=aux,
        ghost_microbatch=ghost_microbatch))(params, batch)

    # per-example norms: vmap reference computed directly
    def one_norm(ex):
        g = jax.grad(loss_one)(params, ex, jax.random.fold_in(rng, 0))
        return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                            for l in jax.tree_util.tree_leaves(g)))

    vmap_norms = jax.jit(jax.vmap(one_norm))(batch)
    _, ghost_norms = jax.jit(lambda p, b: ghost_per_example_norms(
        loss_one, p, b, rng=jax.random.fold_in(rng, 0),
        hooked_mask=model.ghost_mask(p), aux=aux,
        microbatch=ghost_microbatch))(params, batch)
    return vm, gh, vmap_norms, ghost_norms


def assert_tree_close(a, b, rtol=2e-4, atol=2e-5):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


# --------------------------------------------------------------------------- #
# parity: grad sums, per-example norms, metrics
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("fmt", ["none", "luq_fp4"])
@pytest.mark.parametrize("family", ["dense_lm", "resnet"])
def test_ghost_matches_vmap(family, fmt):
    cfg = lm_cfg() if family == "dense_lm" else resnet_cfg()
    _assert_parity(cfg, fmt)


def test_ghost_matches_vmap_densenet():
    """DenseNet shares resnet's conv_ghost_mask — parity guards the
    leaf-naming convention the mask relies on (a conv leaf renamed out of
    the mask would silently drop its norm contribution)."""
    _assert_parity(densenet_cfg(), "luq_fp4", B=4)


def _assert_parity(cfg, fmt, B=6):
    (gv, mv), (gg, mg), vmap_norms, ghost_norms = both_paths(cfg, fmt, B=B)
    assert_tree_close(gv, gg)
    np.testing.assert_allclose(np.asarray(ghost_norms),
                               np.asarray(vmap_norms), rtol=1e-4)
    for k in ("loss", "grad_norm_mean", "grad_norm_max", "clip_fraction"):
        np.testing.assert_allclose(float(mv[k]), float(mg[k]), rtol=1e-4,
                                   atol=1e-6)


def test_ghost_matches_vmap_untied_head():
    """Untied lm_head: the head hook covers a separate leaf (no gather
    cross term) — full hook coverage must still match vmap."""
    cfg = lm_cfg(tie_embeddings=False)
    (gv, _), (gg, _), vn, gn = both_paths(cfg, "luq_fp4")
    assert_tree_close(gv, gg)
    np.testing.assert_allclose(np.asarray(gn), np.asarray(vn), rtol=1e-4)


def test_ghost_matches_vmap_no_aux_fallback():
    """Without GhostAux the embedding/head/norm leaves go through the
    vmapped norm-only fallback — the pre-full-hook formulation stays a
    supported (and correct) configuration."""
    (gv, _), (gg, _), vn, gn = both_paths(lm_cfg(), "luq_fp4",
                                          use_aux=False)
    assert_tree_close(gv, gg)
    np.testing.assert_allclose(np.asarray(gn), np.asarray(vn), rtol=1e-4)


def test_ghost_microbatched_pass1_identical():
    """ghost_microbatch chunks pass 1 with a lax.scan; per-example
    independence + per-example quantization make it numerically
    equivalent to the whole-batch vmap (and to the vmap grad engine)."""
    (gv, _), (gg, mg), vn, gn = both_paths(lm_cfg(), "luq_fp4", B=6,
                                           ghost_microbatch=2)
    assert_tree_close(gv, gg)
    np.testing.assert_allclose(np.asarray(gn), np.asarray(vn), rtol=1e-4)
    with pytest.raises(ValueError, match="not divisible"):
        both_paths(lm_cfg(), "none", B=6, ghost_microbatch=4)


def test_dense_lm_zero_fallback_params():
    """REGRESSION: with the GhostAux hooks (embedding gather Gram,
    single-chunk LM head, rmsnorm scale taps) dense_lm ghost pass 1 must
    run with ZERO vmapped-fallback parameters, tied or untied."""
    for cfg in (lm_cfg(), lm_cfg(tie_embeddings=False)):
        model = build_model(cfg, QuantConfig(fmt="none"))
        params = model.init(jax.random.PRNGKey(0))
        qflags = jnp.ones((cfg.policy_len(),), jnp.float32)
        aux = model.ghost_aux(qflags)
        est = per_example_state_bytes(params, model.ghost_mask(params), 32,
                                      aux=aux)
        assert est["params_nonhooked"] == 0, (cfg.tie_embeddings, est)
        assert est["ghost_bytes"] == 0


def test_ghost_dilated_grouped_conv_fallback():
    """Dilated / grouped convs are outside the patches unfold identity;
    they must fall back PER LAYER (direct norm of the backward's dw)
    instead of failing the family — parity on a toy model using both."""

    def loss(params, ex, rng):
        del rng
        x = ex["x"][None]
        h = qconv2d(x, params["w1"], seed=jnp.uint32(3),
                    flag=jnp.float32(1.0), fmt="luq_fp4",
                    rhs_dilation=(2, 2))
        h = jax.nn.relu(h)
        h = qconv2d(h, params["w2"], seed=jnp.uint32(7),
                    flag=jnp.float32(1.0), fmt="luq_fp4", feature_groups=2)
        return jnp.sum(h.mean(axis=(1, 2)) ** 2)

    def pel(params, batch, rng):
        return jax.vmap(lambda ex: loss(params, ex, rng))(batch)

    k = jax.random.PRNGKey(0)
    params = {"w1": jax.random.normal(k, (3, 3, 4, 8)) * 0.2,
              "w2": jax.random.normal(jax.random.fold_in(k, 1),
                                      (3, 3, 4, 8)) * 0.2}
    batch = {"x": jax.random.normal(jax.random.fold_in(k, 2), (4, 8, 8, 4))}
    gv, mv = jax.jit(lambda p, b: per_example_clipped_grad_sum(
        loss, p, b, clip_norm=0.5, microbatch_size=4,
        rng=jax.random.PRNGKey(9)))(params, batch)
    gg, mg = jax.jit(lambda p, b: ghost_clipped_grad_sum(
        loss, pel, p, b, clip_norm=0.5, rng=jax.random.PRNGKey(9),
        hooked_mask={"w1": True, "w2": True}))(params, batch)
    assert_tree_close(gv, gg)
    np.testing.assert_allclose(float(mv["grad_norm_max"]),
                               float(mg["grad_norm_max"]), rtol=1e-4)


def test_ghost_matches_vmap_strided_bottleneck():
    """ResNet-50-style bottleneck blocks: stride-2 convs + projections."""
    cfg = resnet_cfg(resnet_blocks=(3, 3, 2, 1))   # bottleneck threshold > 8
    (gv, _), (gg, _), vn, gn = both_paths(cfg, "none", B=4)
    assert_tree_close(gv, gg)
    np.testing.assert_allclose(np.asarray(gn), np.asarray(vn), rtol=1e-4)


def test_ghost_clips_when_norms_exceed():
    """Small clip norm: every example clipped, sums bounded."""
    cfg = resnet_cfg()
    C = 0.05
    (_, mv), (gg, mg), _, _ = both_paths(cfg, "none", clip_norm=C)
    assert float(mg["clip_fraction"]) == 1.0 == float(mv["clip_fraction"])
    total = float(jnp.sqrt(sum(
        jnp.sum(jnp.square(l)) for l in jax.tree_util.tree_leaves(gg))))
    assert total <= 6 * C + 1e-5


def test_ghost_partial_quant_flags():
    """Mixed DPQuant policy (some layers quantized) keeps parity."""
    cfg = lm_cfg()
    model = build_model(cfg, QuantConfig(fmt="luq_fp4"))
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4)
    qflags = jnp.asarray([1.0, 0.0], jnp.float32)

    def loss_one(p, ex, r):
        b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
        return model.loss_fn(p, b1, r, qflags)

    def pel(p, b, r):
        return model.per_example_loss(p, b, r, qflags)

    rng = jax.random.PRNGKey(7)
    gv, _ = jax.jit(lambda p, b: per_example_clipped_grad_sum(
        loss_one, p, b, clip_norm=1.0, microbatch_size=4, rng=rng))(
            params, batch)
    gg, _ = jax.jit(lambda p, b: ghost_clipped_grad_sum(
        loss_one, pel, p, b, clip_norm=1.0, rng=rng,
        hooked_mask=model.ghost_mask(p)))(params, batch)
    assert_tree_close(gv, gg)


def test_ghost_engine_dp_grad_fn():
    """make_dp_grad_fn(grad_mode='ghost') adds identical noise to matching
    clipped sums -> noisy grads match the vmap engine."""
    cfg = resnet_cfg()
    model = build_model(cfg, QuantConfig(fmt="none"))
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4)
    qflags = jnp.zeros((cfg.policy_len(),), jnp.float32)

    def loss_one(p, ex, r):
        b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
        return model.loss_fn(p, b1, r, qflags)

    def pel(p, b, r):
        return model.per_example_loss(p, b, r, qflags)

    outs = {}
    for mode in ("vmap", "ghost"):
        dp = DPConfig(grad_mode=mode, microbatch_size=4, clip_norm=1.0,
                      noise_multiplier=0.5)
        fn = make_dp_grad_fn(loss_one, dp,
                             per_example_loss=pel,
                             ghost_mask=model.ghost_mask)
        outs[mode] = jax.jit(fn)(params, batch, jax.random.PRNGKey(3))[0]
    assert_tree_close(outs["vmap"], outs["ghost"])


# --------------------------------------------------------------------------- #
# no-hook degenerate case: pure fallback == vmap path exactly
# --------------------------------------------------------------------------- #
def test_ghost_all_fallback_matches_vmap():
    def quad_loss(params, ex, rng):
        del rng
        return 0.5 * jnp.sum((params["w"] * ex["x"] - ex["y"]) ** 2)

    def pel(params, batch, rng):
        return jax.vmap(lambda ex: quad_loss(params, ex, rng))(batch)

    params = {"w": jnp.arange(1.0, 6.0)}
    key = jax.random.PRNGKey(0)
    batch = {"x": jax.random.normal(key, (8, 5)),
             "y": jax.random.normal(jax.random.fold_in(key, 1), (8, 5))}
    gv, mv = per_example_clipped_grad_sum(
        quad_loss, params, batch, clip_norm=0.5, microbatch_size=8,
        rng=jax.random.PRNGKey(0))
    gg, mg = ghost_clipped_grad_sum(
        quad_loss, pel, params, batch, clip_norm=0.5,
        rng=jax.random.PRNGKey(0), hooked_mask={"w": False})
    np.testing.assert_allclose(np.asarray(gg["w"]), np.asarray(gv["w"]),
                               rtol=1e-6)
    np.testing.assert_allclose(float(mg["grad_norm_max"]),
                               float(mv["grad_norm_max"]), rtol=1e-6)


# --------------------------------------------------------------------------- #
# trainer integration: both epoch executors accept the mode
# --------------------------------------------------------------------------- #
def test_ghost_both_executors_and_vs_vmap():
    from repro.data.synthetic import ImageClassDataset
    from repro.train_loop import Trainer

    model = resnet_cfg()
    ds = ImageClassDataset(n=64, num_classes=8, image_size=16, noise=0.4)

    def run_of(mode, executor):
        return RunConfig(
            model=model, quant=QuantConfig(fmt="luq_fp4"),
            dp=DPConfig(enabled=True, clip_norm=1.0, noise_multiplier=1.0,
                        microbatch_size=8, quant_fraction=0.6,
                        analysis_interval=2, analysis_reps=1,
                        grad_mode=mode),
            optim=OptimConfig(name="sgd", lr=0.5),
            global_batch=8, steps_per_epoch=2, steps=100, seed=0,
            epoch_executor=executor)

    params = {}
    for mode in ("vmap", "ghost"):
        for executor in ("scan", "loop"):
            tr = Trainer(run_of(mode, executor), ds, mode="static")
            tr.train(1)
            params[(mode, executor)] = tr.params

    # scan and loop are numerically interchangeable within each mode
    # (ghost's Gram/patch einsums compile with different fusion inside
    # lax.scan, so equivalence is fp32-tolerance, not bitwise)
    for mode in ("vmap", "ghost"):
        assert_tree_close(params[(mode, "scan")], params[(mode, "loop")],
                          rtol=1e-4, atol=1e-5)
    # and the two grad modes train identically on a fixed seed
    assert_tree_close(params[("vmap", "scan")], params[("ghost", "scan")],
                      rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------- #
# validation + introspection
# --------------------------------------------------------------------------- #
def test_grad_mode_validation():
    with pytest.raises(ValueError, match="grad_mode"):
        validate_grad_mode(DPConfig(grad_mode="bogus"))
    with pytest.raises(ValueError, match="partial_accum"):
        validate_grad_mode(DPConfig(grad_mode="ghost", partial_accum=True))
    with pytest.raises(ValueError, match="fused"):
        validate_grad_mode(DPConfig(grad_mode="ghost",
                                    clip_backend="fused"))
    with pytest.raises(ValueError, match="ghost_microbatch"):
        validate_grad_mode(DPConfig(grad_mode="ghost", ghost_microbatch=-1))
    with pytest.raises(ValueError, match="ghost_sharded"):
        validate_grad_mode(DPConfig(grad_mode="ghost",
                                    ghost_sharded="sideways"))
    model = build_model(resnet_cfg(), QuantConfig(fmt="none"))
    hookless = dataclasses.replace(model, per_example_loss=None)
    with pytest.raises(ValueError, match="ghost hooks"):
        validate_grad_mode(DPConfig(grad_mode="ghost"), hookless)
    with pytest.raises(ValueError, match="per_example_loss"):
        make_dp_grad_fn(lambda p, e, r: 0.0, DPConfig(grad_mode="ghost"))


def test_ghost_mask_structure():
    """Masks mirror params; hooked set = projections/convs only."""
    for cfg in (lm_cfg(), resnet_cfg()):
        model = build_model(cfg, QuantConfig(fmt="none"))
        params = model.init(jax.random.PRNGKey(0))
        mask = model.ghost_mask(params)
        assert (jax.tree_util.tree_structure(mask)
                == jax.tree_util.tree_structure(params))
        flat = list(zip(
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree_util.tree_leaves(mask)))
        hooked = sum(bool(m) for _, m in flat)
        assert 0 < hooked < len(flat)
        for (path, _), m in flat:
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name in ("scale", "bias", "b"):     # norms + head bias
                assert not m, f"norm/bias leaf {path} must not be hooked"


def test_per_example_state_bytes():
    model = build_model(lm_cfg(), QuantConfig(fmt="none"))
    params = model.init(jax.random.PRNGKey(0))
    est = per_example_state_bytes(params, model.ghost_mask(params), 32)
    assert est["params_nonhooked"] < est["params_total"]
    assert est["ghost_bytes"] < est["vmap_bytes"]
    assert est["vmap_bytes"] == 32 * est["params_total"] * 4
