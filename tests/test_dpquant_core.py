"""DPQuant scheduler: Algorithm 1 + 2 semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.config import DPConfig
from repro.core.loss_impact import compute_loss_impact
from repro.core.policy import (QuantPolicy, full_policy, random_policy,
                               singleton_policies, union_policy)
from repro.core.scheduler import DPQuantScheduler
from repro.core.selection import (sample_without_replacement, select_targets,
                                  selection_probs)
from repro.dp.accountant import RDPAccountant


def test_policy_flags():
    p = QuantPolicy((0, 2), 4)
    np.testing.assert_array_equal(np.asarray(p.flags()), [1, 0, 1, 0])
    assert len(full_policy(5)) == 5
    u = union_policy([QuantPolicy((0,), 3), QuantPolicy((2,), 3)], 3)
    assert u.layers == (0, 2)


def test_selection_probs_prefer_low_impact():
    scores = np.array([0.0, 1.0, 0.5])
    p = selection_probs(scores, beta=5.0)
    assert p[0] > p[2] > p[1]
    np.testing.assert_allclose(p.sum(), 1.0)


def test_beta_limits():
    scores = np.array([0.0, 1.0, 0.2, 0.8])
    p0 = selection_probs(scores, beta=0.0)
    np.testing.assert_allclose(p0, 0.25)             # PLS limit
    ph = selection_probs(scores, beta=1e4)
    assert ph[0] > 0.99                               # deterministic limit


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=2, max_value=12), st.integers(min_value=1, max_value=12))
def test_sampling_without_replacement(n, m):
    rng = np.random.RandomState(0)
    probs = rng.rand(n)
    probs /= probs.sum()
    idx = sample_without_replacement(probs, m, rng)
    assert len(idx) == min(m, n)
    assert len(set(idx)) == len(idx)                  # no repeats


def test_select_targets_respects_budget():
    rng = np.random.RandomState(0)
    pols = singleton_policies(10)
    scores = np.zeros(10)
    pol = select_targets(scores, pols, beta=1.0, m=7, rng=rng, n_layers=10)
    assert len(pol) == 7


def test_scheduler_modes():
    dp = DPConfig(quant_fraction=0.5)
    for mode in ("static", "pls", "dpquant"):
        s = DPQuantScheduler(n_layers=8, dp=dp, mode=mode, seed=1)
        p1 = s.select(0)
        p2 = s.select(1)
        assert len(p1) == 4 and len(p2) == 4
        if mode == "static":
            assert p1.layers == p2.layers             # fixed subset
    # pls rotates with overwhelming probability across several epochs
    s = DPQuantScheduler(n_layers=8, dp=dp, mode="pls", seed=2)
    seen = {s.select(e).layers for e in range(6)}
    assert len(seen) > 1


def test_loss_impact_identifies_sensitive_layer():
    """Toy probe: quantizing layer 1 hurts the loss, layer 0 doesn't.
    The estimator must rank layer 1 as higher impact."""
    def probe_step(params, opt, batch, seed, flags):
        loss = 1.0 + 5.0 * flags[1] + 0.01 * flags[0]
        return params, opt, {"loss": jnp.float32(loss)}

    pols = singleton_policies(2)
    scores = compute_loss_impact(
        probe_step=probe_step, params={}, opt_state=(), policies=pols,
        batches=[{}, {}], reps=2, seed=0, measure_clip=10.0,
        measure_noise=0.01, sample_rate=0.01, accountant=None,
        ema_scores=None, ema_alpha=0.3)
    assert scores[1] > scores[0]


def test_loss_impact_charges_accountant():
    def probe_step(params, opt, batch, seed, flags):
        return params, opt, {"loss": jnp.float32(1.0)}

    acc = RDPAccountant()
    compute_loss_impact(
        probe_step=probe_step, params={}, opt_state=(), policies=singleton_policies(3),
        batches=[{}], reps=1, seed=0, measure_clip=0.01, measure_noise=0.5,
        sample_rate=0.05, accountant=acc, ema_scores=None, ema_alpha=0.3)
    assert len(acc.history) == 1
    assert acc.history[0].label == "analysis"
    assert acc.get_epsilon(1e-5)[0] > 0


def test_loss_impact_privatized():
    """With tiny clip + large noise the output is dominated by noise ->
    different seeds give different scores (the release is randomized)."""
    def probe_step(params, opt, batch, seed, flags):
        return params, opt, {"loss": jnp.float32(float(flags.sum()))}

    pols = singleton_policies(4)
    kw = dict(probe_step=probe_step, params={}, opt_state=(), policies=pols,
              batches=[{}], reps=1, measure_clip=0.01, measure_noise=0.5,
              sample_rate=0.01, accountant=None, ema_scores=None,
              ema_alpha=0.3)
    s1 = compute_loss_impact(seed=1, **kw)
    s2 = compute_loss_impact(seed=2, **kw)
    assert not np.allclose(s1, s2)
    # and clipped: |pre-noise release| <= C
    assert np.linalg.norm(s1) < 0.01 + 5 * 0.5 * 0.01 * np.sqrt(4)


def test_scheduler_state_roundtrip():
    dp = DPConfig(quant_fraction=0.75)
    s = DPQuantScheduler(n_layers=8, dp=dp, mode="dpquant", seed=3)
    s.scores = np.arange(8.0)
    s.select(0)
    state = s.state_dict()
    s2 = DPQuantScheduler(n_layers=8, dp=dp, mode="dpquant", seed=99)
    s2.load_state_dict(state)
    np.testing.assert_array_equal(s2.scores, s.scores)
    assert s2.current.layers == s.current.layers
    # same RNG continuation
    assert s.select(1).layers == s2.select(1).layers


def test_scheduler_roundtrip_resume_mid_training():
    """Checkpoint/restore mid-training: a restored scheduler must continue
    exactly — same EMA continuation (n_analyses survives the round-trip),
    same selections, same analysis cadence."""
    def probe_step(params, opt, batch, seed, flags):
        loss = 1.0 + float(np.sum(np.asarray(flags) * np.arange(1, 7)))
        return params, opt, {"loss": jnp.float32(loss)}

    def analyze(s, epoch, seed):
        return s.maybe_analyze(
            probe_step=probe_step, params={}, opt_state=(), batches=[{}],
            sample_rate=0.01, accountant=None, epoch=epoch, seed=seed)

    dp = DPConfig(quant_fraction=0.5, analysis_interval=2, analysis_reps=1)
    s = DPQuantScheduler(n_layers=6, dp=dp, mode="dpquant", seed=7)
    # epochs 0..2: two analyses (0, 2) and three selections
    for e in range(3):
        analyze(s, e, seed=100 + e)
        s.select(e)
    assert s.n_analyses == 2

    s2 = DPQuantScheduler(n_layers=6, dp=dp, mode="dpquant", seed=7)
    s2.load_state_dict(s.state_dict())
    assert s2.n_analyses == s.n_analyses
    np.testing.assert_array_equal(s2.scores, s.scores)
    # continue both for three more epochs (epoch 4 triggers an EMA update,
    # which only behaves identically if n_analyses was restored)
    for e in range(3, 6):
        ran1 = analyze(s, e, seed=100 + e)
        ran2 = analyze(s2, e, seed=100 + e)
        assert ran1 == ran2 == (e % 2 == 0)
        assert s.select(e).layers == s2.select(e).layers
    np.testing.assert_allclose(s2.scores, s.scores)
    assert s.n_analyses == s2.n_analyses == 3
