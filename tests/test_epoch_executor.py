"""Scan-based epoch executor: equivalence with the legacy per-step loop."""
import dataclasses

import numpy as np
import jax

from repro.config import (DPConfig, ModelConfig, OptimConfig, QuantConfig,
                          RunConfig)
from repro.data.synthetic import ImageClassDataset
from repro.train_loop import Trainer


def small_run(executor="scan", *, chunk=0, steps_per_epoch=3, seed=0):
    model = ModelConfig(name="cnn", family="resnet", resnet_blocks=(1, 1),
                        num_classes=8, image_size=16,
                        compute_dtype="float32")
    return RunConfig(
        model=model, quant=QuantConfig(fmt="luq_fp4"),
        dp=DPConfig(enabled=True, clip_norm=1.0, noise_multiplier=1.0,
                    microbatch_size=16, quant_fraction=0.6,
                    analysis_interval=2, analysis_reps=1),
        optim=OptimConfig(name="sgd", lr=0.5),
        global_batch=16, steps_per_epoch=steps_per_epoch, steps=100,
        seed=seed, epoch_executor=executor, epoch_chunk=chunk)


def train_both(run_a, run_b, epochs=3, mode="dpquant"):
    ds = ImageClassDataset(n=256, num_classes=8, image_size=16, noise=0.4)
    out = []
    for run in (run_a, run_b):
        tr = Trainer(run, ds, mode=mode)
        hist = tr.train(epochs)
        out.append((tr, hist))
    return out


def assert_trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_scan_matches_loop_bitwise():
    """Same seed -> identical params, opt state, losses, and epsilon.

    Covers an analysis epoch (interval 2, epochs 0 and 2) so the probe
    path and the per-epoch accountant charging are exercised too.
    """
    (tr_loop, hist_loop), (tr_scan, hist_scan) = train_both(
        small_run("loop"), small_run("scan"))
    assert tr_loop.step == tr_scan.step
    assert_trees_equal(tr_loop.params, tr_scan.params)
    assert_trees_equal(tr_loop.opt_state, tr_scan.opt_state)
    np.testing.assert_array_equal([h.loss for h in hist_loop],
                                  [h.loss for h in hist_scan])
    assert (tr_loop.accountant.get_epsilon(1e-5)
            == tr_scan.accountant.get_epsilon(1e-5))
    # per-step charging merges into the same history as per-epoch charging
    assert (tr_loop.accountant.total_steps("train")
            == tr_scan.accountant.total_steps("train"))
    assert len(tr_loop.accountant.history) == len(tr_scan.accountant.history)
    # both executors consumed the Poisson RNG stream identically
    s1, s2 = tr_loop.sampler.sample(), tr_scan.sampler.sample()
    np.testing.assert_array_equal(s1, s2)


def test_chunked_scan_matches_whole_epoch():
    """epoch_chunk bounds memory without changing results."""
    (tr_whole, _), (tr_chunk, _) = train_both(
        small_run("scan", chunk=0, steps_per_epoch=4),
        small_run("scan", chunk=3, steps_per_epoch=4), epochs=2)
    assert_trees_equal(tr_whole.params, tr_chunk.params)
    assert (tr_whole.accountant.get_epsilon(1e-5)
            == tr_chunk.accountant.get_epsilon(1e-5))


def test_scan_is_default_and_validated():
    run = small_run("scan")
    assert RunConfig(model=run.model).epoch_executor == "scan"
    try:
        Trainer(dataclasses.replace(run, epoch_executor="bogus"),
                ImageClassDataset(n=64, num_classes=8, image_size=16))
        raise AssertionError("expected ValueError for bogus executor")
    except ValueError:
        pass


def test_scan_with_dp_disabled():
    run = dataclasses.replace(small_run("scan"),
                              dp=DPConfig(enabled=False, quant_fraction=0.6))
    ds = ImageClassDataset(n=128, num_classes=8, image_size=16, noise=0.4)
    tr = Trainer(run, ds, mode="static")
    hist = tr.train(2)
    assert np.isfinite(hist[-1].loss)
    assert hist[-1].eps == 0.0
    assert tr.accountant.total_steps() == 0
