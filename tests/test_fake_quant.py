"""Policy-gated fake-quant einsum/conv: flag semantics + custom VJP."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.quant.fake_quant import qeinsum, qconv2d


def test_flag_zero_is_exact():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 8))
    w = jax.random.normal(jax.random.fold_in(key, 1), (8, 16))

    def loss(w, flag):
        return (qeinsum("ab,bc->ac", x, w, seed=jnp.uint32(1),
                        flag=flag) ** 2).sum()

    g0 = jax.grad(loss)(w, jnp.float32(0.0))
    gref = jax.grad(lambda w: (jnp.einsum("ab,bc->ac", x, w) ** 2).sum())(w)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(gref), rtol=1e-5)


def test_flag_one_changes_value_but_stays_finite():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (16, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 8))
    y0 = qeinsum("ab,bc->ac", x, w, seed=jnp.uint32(3), flag=jnp.float32(0))
    y1 = qeinsum("ab,bc->ac", x, w, seed=jnp.uint32(3), flag=jnp.float32(1))
    assert not np.allclose(np.asarray(y0), np.asarray(y1))
    assert np.isfinite(np.asarray(y1)).all()
    # quantization error should be moderate at fp4 for gaussian data
    rel = np.linalg.norm(np.asarray(y1 - y0)) / np.linalg.norm(np.asarray(y0))
    assert rel < 1.0, rel


@pytest.mark.parametrize("spec,xs,ws", [
    ("ab,bc->ac", (4, 8), (8, 6)),
    ("bsd,dhk->bshk", (2, 5, 8), (8, 3, 4)),
    ("bshk,hkd->bsd", (2, 5, 3, 4), (3, 4, 8)),
    ("ecd,edf->ecf", (3, 4, 8), (3, 8, 6)),
])
def test_vjp_matches_autodiff_when_flag_zero(spec, xs, ws):
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, xs)
    w = jax.random.normal(jax.random.fold_in(key, 1), ws)

    def f_q(x, w):
        return qeinsum(spec, x, w, seed=jnp.uint32(0),
                       flag=jnp.float32(0)).sum()

    def f_ref(x, w):
        return jnp.einsum(spec, x, w).sum()

    gx_q, gw_q = jax.grad(f_q, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx_q), np.asarray(gx_r), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gw_q), np.asarray(gw_r), rtol=1e-5)


def test_conv_flag_zero_exact():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (2, 8, 8, 3))
    w = jax.random.normal(jax.random.fold_in(key, 1), (3, 3, 3, 4))

    def f_q(w):
        return (qconv2d(x, w, seed=jnp.uint32(0), flag=jnp.float32(0)) ** 2).sum()

    def f_ref(w):
        dn = jax.lax.conv_dimension_numbers(x.shape, w.shape,
                                            ("NHWC", "HWIO", "NHWC"))
        return (jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=dn) ** 2).sum()

    np.testing.assert_allclose(np.asarray(jax.grad(f_q)(w)),
                               np.asarray(jax.grad(f_ref)(w)), rtol=1e-5)


def test_vmap_per_example_grads():
    """The DP path: vmap(grad) over examples with an unbatched flag."""
    key = jax.random.PRNGKey(4)
    xb = jax.random.normal(key, (6, 4, 8))
    w = jax.random.normal(jax.random.fold_in(key, 1), (8, 5))

    def loss(w, xe, flag):
        return (qeinsum("ab,bc->ac", xe, w, seed=jnp.uint32(3),
                        flag=flag) ** 2).mean()

    g = jax.vmap(jax.grad(loss), in_axes=(None, 0, None))(
        w, xb, jnp.float32(1.0))
    assert g.shape == (6, 8, 5)
    assert np.isfinite(np.asarray(g)).all()


def test_rng_folds_distinct_within_step_and_across_seeds():
    """Regression for the fold collision: ``fold_in(key, seed + fold)`` made
    (seed=s, fold=1) collide with (seed=s+1, fold=0), correlating the
    quantization draws of adjacent steps/GEMMs.  Seed and fold must be
    folded separately, giving six distinct GEMM-input draws per step and no
    overlap between consecutive seeds."""
    from repro.quant.fake_quant import _maybe_quant
    x = jax.random.normal(jax.random.PRNGKey(9), (64, 64))
    flag = jnp.float32(1.0)

    def draw(seed, fold):
        return np.asarray(_maybe_quant(x, jnp.uint32(seed), fold,
                                       "luq_fp4", flag))

    # the six GEMM-input folds of one step are pairwise distinct draws
    step_draws = [draw(5, f) for f in range(6)]
    for i in range(6):
        for j in range(i + 1, 6):
            assert not np.array_equal(step_draws[i], step_draws[j]), (i, j)
    # and no draw of seed s+1 collides with any draw of seed s
    next_draws = [draw(6, f) for f in range(6)]
    for i, a in enumerate(step_draws):
        for j, b in enumerate(next_draws):
            assert not np.array_equal(a, b), (i, j)
    # determinism: same (seed, fold) -> identical draw
    np.testing.assert_array_equal(draw(5, 3), draw(5, 3))


def test_flag_switch_no_recompile():
    """Policy flips are traced values — one compilation serves both."""
    x = jnp.ones((4, 8))
    w = jnp.ones((8, 4))
    calls = {"n": 0}

    @jax.jit
    def f(w, flag):
        calls["n"] += 1
        return qeinsum("ab,bc->ac", x, w, seed=jnp.uint32(0), flag=flag).sum()

    f(w, jnp.float32(0)).block_until_ready()
    f(w, jnp.float32(1)).block_until_ready()
    assert calls["n"] == 1
