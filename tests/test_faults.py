"""FaultPlan determinism/consumption + ServeMetrics failure-bucket tests."""
import json

import pytest

from repro.runtime.faults import (DEFAULT_FREEZE_READS, FAULT_KINDS,
                                  FaultEvent, FaultPlan)
from repro.serve.metrics import ServeMetrics


# --------------------------------------------------------------------- #
# FaultPlan
# --------------------------------------------------------------------- #
def test_generate_is_seed_deterministic():
    a = FaultPlan.generate(7, horizon=50, n_slots=4, n_replicas=3)
    b = FaultPlan.generate(7, horizon=50, n_slots=4, n_replicas=3)
    assert a.pending == b.pending
    c = FaultPlan.generate(8, horizon=50, n_slots=4, n_replicas=3)
    assert a.pending != c.pending
    # default: one event per kind
    assert sorted(e.kind for e in a.pending) == sorted(FAULT_KINDS)


def test_take_consumes_at_or_before_counter():
    plan = FaultPlan([FaultEvent(kind="decode_fail", at=3),
                      FaultEvent(kind="decode_fail", at=10),
                      FaultEvent(kind="slot_corrupt", at=3)])
    assert plan.take("decode_fail", 2) == []
    # <= semantics: a skipped counter value still fires the event
    due = plan.take("decode_fail", 5)
    assert [e.at for e in due] == [3]
    # other kinds are untouched
    assert plan.has_pending("slot_corrupt")
    assert plan.has_pending("decode_fail")
    assert plan.take("decode_fail", 10)[0].at == 10
    assert not plan.has_pending("decode_fail")


def test_log_records_fired_events_as_json():
    plan = FaultPlan([FaultEvent(kind="clock_freeze", at=1, duration=4)],
                     seed=9)
    plan.take("clock_freeze", 2)
    blob = json.loads(plan.log_json(extra={"run": "test"}))
    assert blob["seed"] == 9
    assert blob["run"] == "test"
    assert blob["fired"][0]["kind"] == "clock_freeze"
    assert blob["fired"][0]["fired_at"] == 2
    assert blob["pending"] == []


def test_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(kind="nope", at=1)
    with pytest.raises(ValueError):
        FaultEvent(kind="preempt", at=-1)
    with pytest.raises(ValueError):
        FaultPlan.generate(0, horizon=1)
    with pytest.raises(ValueError):
        FaultPlan.generate(0, horizon=10, kinds=("bogus",))


def test_generate_freeze_duration_default():
    plan = FaultPlan.generate(0, kinds=("clock_freeze",), horizon=10)
    (ev,) = plan.pending
    assert ev.duration == DEFAULT_FREEZE_READS


# --------------------------------------------------------------------- #
# ServeMetrics failure buckets (satellite: never crash on shed /
# never-admitted requests)
# --------------------------------------------------------------------- #
def test_metrics_summary_survives_shed_and_queue_timeout():
    m = ServeMetrics()
    # rid 0 completes normally
    m.on_submit(0, 4, 0.0)
    m.on_admit(0, 1.0)
    m.on_first_token(0, 1.5)
    m.on_complete(0, 2.0, n_generated=3)
    # rid 1 shed at submit; rid 2 expires in queue — neither was admitted
    m.on_submit(1, 4, 0.5)
    m.on_shed(1, 0.5)
    m.on_submit(2, 4, 0.6)
    m.on_queue_timeout(2, 9.0)
    s = m.summary()           # must not raise on the None-field rows
    assert s["n_requests"] == 1
    assert s["shed"] == 1
    assert s["deadline_missed"] == 1
    assert s["n_rejected"] == 2
    rows = m.per_request()
    assert [r["request_id"] for r in rows] == [0]
    rej = m.rejected()
    assert [(r["request_id"], r["status"]) for r in rej] == [
        (1, "shed"), (2, "timed_out")]


def test_metrics_timed_out_in_flight_counts_partial_tokens():
    m = ServeMetrics()
    m.on_submit(0, 4, 0.0)
    m.on_admit(0, 1.0)
    m.on_first_token(0, 1.5)
    m.on_complete(0, 5.0, n_generated=2, status="timed_out")
    s = m.summary()
    assert s["n_requests"] == 0          # percentiles are ok-only
    assert s["total_new_tokens"] == 2    # partial tokens still counted
    assert s["deadline_missed"] == 1
    assert s["n_timed_out"] == 1
    (row,) = m.per_request()
    assert row["status"] == "timed_out"
    assert row["latency_s"] == 5.0


def test_metrics_recovered_counts_ok_after_retry():
    m = ServeMetrics()
    m.on_submit(0, 4, 0.0)
    m.on_admit(0, 1.0)
    m.on_retry(0)
    m.on_admit(0, 3.0)               # re-admission keeps the first stamp
    m.on_first_token(0, 3.5)
    m.on_complete(0, 4.0, n_generated=5)
    assert m.timings[0].admitted == 1.0
    assert m.retried == 1
    assert m.recovered == 1
    assert m.summary()["recovered"] == 1


def test_metrics_rejects_unknown_status():
    m = ServeMetrics()
    m.on_submit(0, 4, 0.0)
    with pytest.raises(ValueError):
        m.on_complete(0, 1.0, n_generated=0, status="exploded")


def test_metrics_empty_summary_has_counter_keys():
    s = ServeMetrics().summary()
    for key in ("shed", "retried", "deadline_missed", "recovered",
                "faults_injected", "degraded_events", "n_rejected",
                "tokens_per_sec"):
        assert key in s
