"""Sharded ghost engine: 8-fake-device parity (subprocess — the main test
process must keep the default 1-CPU-device view).

Acceptance contract (ISSUE 5 / docs/ARCHITECTURE.md): sharded ghost
(per-shard squared-norm taps + ONE psum of the clipped grad sums) on an
8-fake-device mesh matches single-device ghost to fp32 tolerance, under
BOTH epoch executors.
"""
import subprocess
import sys
import textwrap


def _run(code: str, timeout: int = 600):
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         cwd=".")
    assert res.returncode == 0, res.stderr + res.stdout
    assert "OK" in res.stdout, res.stdout


def test_sharded_ghost_step_matches_single_device():
    """One ghost DP step: driver-level parity of grads + metrics between
    the shard_map formulation on (8, 1) and single-device ghost, with the
    full GhostAux hook coverage and a microbatched pass 1."""
    _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.config import ModelConfig, QuantConfig
        from repro.dp.ghost import (ghost_clipped_grad_sum,
                                    sharded_ghost_clipped_grad_sum)
        from repro.models.registry import build_model
        from repro.launch.mesh import make_compat_mesh

        cfg = ModelConfig(name="g", family="dense_lm", n_layers=2,
                          d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                          d_ff=64, vocab_size=128,
                          compute_dtype="float32", remat=True)
        model = build_model(cfg, QuantConfig(fmt="luq_fp4"))
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)}
        qflags = jnp.ones((cfg.policy_len(),), jnp.float32)

        def loss_one(p, ex, r):
            b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
            return model.loss_fn(p, b1, r, qflags)

        def pel(p, b, r):
            return model.per_example_loss(p, b, r, qflags)

        rng = jax.random.PRNGKey(42)
        aux = model.ghost_aux(qflags)
        mesh = make_compat_mesh((8, 1), ("data", "model"))
        gu, mu = jax.jit(lambda p, b: ghost_clipped_grad_sum(
            loss_one, pel, p, b, clip_norm=0.8, rng=rng,
            hooked_mask=model.ghost_mask(p), aux=aux))(params, batch)
        gs, ms = jax.jit(lambda p, b: sharded_ghost_clipped_grad_sum(
            loss_one, pel, p, b, clip_norm=0.8, rng=rng,
            hooked_mask=model.ghost_mask(p), aux=aux, mesh=mesh,
            ghost_microbatch=1))(params, batch)
        for (pa, x), (_, y) in zip(
                jax.tree_util.tree_leaves_with_path(gu),
                jax.tree_util.tree_leaves_with_path(gs)):
            np.testing.assert_allclose(
                np.asarray(x), np.asarray(y), rtol=2e-4, atol=2e-5,
                err_msg=jax.tree_util.keystr(pa))
        for k in mu:
            np.testing.assert_allclose(float(mu[k]), float(ms[k]),
                                       rtol=1e-4, atol=1e-6)
        print("OK")
    """)


def test_sharded_ghost_both_executors_match_single_device():
    """Full train-setup parity: ghost on the (8, 1) mesh (auto-sharded)
    under BOTH epoch executors ends at the same params as 1-device ghost
    (fp32 tolerance — Gram einsums fuse differently across programs)."""
    _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.config import (RunConfig, DPConfig, OptimConfig,
                                  QuantConfig, ModelConfig)
        from repro.launch.steps import build_train_setup, build_epoch_fn
        from repro.models.registry import build_model
        from repro.launch.mesh import make_compat_mesh

        cfg = ModelConfig(name="g", family="dense_lm", n_layers=2,
                          d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                          d_ff=64, vocab_size=128,
                          compute_dtype="float32", remat=True)
        model = build_model(cfg, QuantConfig(fmt="luq_fp4"))
        B, S, STEPS = 8, 16, 2
        run = RunConfig(model=cfg, quant=QuantConfig(fmt="luq_fp4"),
                        dp=DPConfig(enabled=True, grad_mode="ghost",
                                    clip_norm=0.8, noise_multiplier=0.5),
                        optim=OptimConfig(name="sgd", lr=0.1),
                        global_batch=B, seq_len=S)
        params0 = model.init(jax.random.PRNGKey(0))
        batches = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (STEPS, B, S), 0, cfg.vocab_size)}
        seeds = jnp.arange(STEPS, dtype=jnp.uint32)
        lrs = jnp.full((STEPS,), 0.1, jnp.float32)
        qflags = jnp.ones((cfg.policy_len(),), jnp.float32)

        results = {}
        for shape in ((1, 1), (8, 1)):
            mesh = make_compat_mesh(shape, ("data", "model"))
            setup = build_train_setup(model, run, mesh)
            opt0 = setup.opt_init_fn(params0)
            # loop executor
            step = jax.jit(setup.step_fn, in_shardings=setup.in_shardings,
                           out_shardings=setup.out_shardings)
            p, o = params0, opt0
            for i in range(STEPS):
                b = {"tokens": batches["tokens"][i]}
                p, o, _ = step(p, o, b, seeds[i], qflags, lrs[i])
            results[(shape, "loop")] = p
            # scan executor (donates params/opt -> fresh copies)
            epoch_fn = build_epoch_fn(setup)
            p2, _, _ = epoch_fn(
                jax.tree_util.tree_map(jnp.copy, params0),
                jax.tree_util.tree_map(jnp.copy, opt0),
                batches, seeds, qflags, lrs)
            results[(shape, "scan")] = p2

        ref = results[((1, 1), "loop")]
        for key, got in results.items():
            for (pa, x), (_, y) in zip(
                    jax.tree_util.tree_leaves_with_path(ref),
                    jax.tree_util.tree_leaves_with_path(got)):
                np.testing.assert_allclose(
                    np.asarray(x), np.asarray(y), rtol=2e-4, atol=2e-4,
                    err_msg=f"{key} {jax.tree_util.keystr(pa)}")
        print("OK")
    """)
