"""Trip-count-aware HLO analyzer vs analytic FLOP counts."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze
from repro.launch import roofline


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_flops():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out.sum()

    sds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    r = analyze(_compile(f, sds, sds).as_text())
    expected = 10 * 2 * 128 ** 3
    assert abs(r["flops"] - expected) / expected < 0.05
    assert not r["warnings"]


def test_nested_scan_flops():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out.sum()

    sds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    r = analyze(_compile(f, sds, sds).as_text())
    expected = 12 * 2 * 128 ** 3
    assert abs(r["flops"] - expected) / expected < 0.05


def test_plain_matmul_exact():
    f = lambda a, b: a @ b
    r = analyze(_compile(
        f, jax.ShapeDtypeStruct((256, 512), jnp.float32),
        jax.ShapeDtypeStruct((512, 128), jnp.float32)).as_text())
    assert r["flops"] == 2 * 256 * 512 * 128


def test_conv_flops_exact():
    def f(x, w):
        dn = jax.lax.conv_dimension_numbers(x.shape, w.shape,
                                            ("NHWC", "HWIO", "NHWC"))
        return jax.lax.conv_general_dilated(x, w, (1, 1), "SAME",
                                            dimension_numbers=dn)
    r = analyze(_compile(
        f, jax.ShapeDtypeStruct((2, 16, 16, 8), jnp.float32),
        jax.ShapeDtypeStruct((3, 3, 8, 4), jnp.float32)).as_text())
    assert r["flops"] == 2 * 2 * 16 * 16 * 4 * 3 * 3 * 8


def test_bytes_scale_with_loop():
    def body_once(x):
        return jnp.tanh(x * 2.0)

    def looped(x):
        def body(c, _):
            return jnp.tanh(c * 2.0), None
        out, _ = jax.lax.scan(body, x, None, length=50)
        return out

    sds = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    b1 = analyze(_compile(body_once, sds).as_text())["bytes"]
    b50 = analyze(_compile(looped, sds).as_text())["bytes"]
    assert b50 > 20 * b1


def test_roofline_terms():
    terms = roofline.derive({}, "", hlo_analysis={
        "flops": 197e12, "bytes": 819e9, "collectives": {"all-reduce": 25e9},
        "collective_bytes": 25e9, "collective_wire_bytes": 50e9,
        "warnings": [], "entry": "main"})
    assert abs(terms.compute_s - 1.0) < 1e-9
    assert abs(terms.memory_s - 1.0) < 1e-9
    assert abs(terms.collective_s - 1.0) < 1e-9


def test_model_flops_moe_active():
    from repro.configs import get_smoke_config
    from repro.models.registry import build_model
    from repro.config import QuantConfig
    cfg = get_smoke_config("kimi-k2-1t-a32b")
    model = build_model(cfg, QuantConfig())
    ap = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    total = roofline.count_params(ap)
    active = roofline.active_params(cfg, ap)
    assert active < total
