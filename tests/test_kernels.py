"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (luq_matmul, luq_quantize, clip_and_sum,
                           ghost_norm_sq)
from repro.kernels import ref
from repro.kernels.luq_quant import luq_quant_2d
from repro.kernels.per_sample_clip import per_sample_clip
from repro.kernels.quant_matmul import quant_matmul


@pytest.mark.parametrize("shape", [(128, 128), (256, 256), (512, 384),
                                   (128, 640)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_luq_kernel_matches_ref(shape, dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, shape, jnp.float32).astype(dtype)
    u = jax.random.uniform(jax.random.fold_in(key, 1), shape, jnp.float32)
    alpha = jnp.max(jnp.abs(x.astype(jnp.float32)))
    got = luq_quant_2d(x, u, alpha, block=(128, 128), interpret=True)
    want = ref.luq_quant_ref(x, u, alpha)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mkn", [(128, 256, 128), (256, 512, 128),
                                 (128, 128, 256)])
@pytest.mark.parametrize("block", [(128, 64, 128), (64, 128, 256)])
def test_quant_matmul_matches_ref(mkn, block):
    m, k, n = mkn
    key = jax.random.PRNGKey(1)
    a = jax.random.normal(key, (m, k), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32)
    ua = jax.random.uniform(jax.random.fold_in(key, 2), (m, k))
    ub = jax.random.uniform(jax.random.fold_in(key, 3), (k, n))
    aa, ab = jnp.max(jnp.abs(a)), jnp.max(jnp.abs(b))
    got = quant_matmul(a, b, ua, ub, aa, ab, block=block, interpret=True)
    want = ref.quant_matmul_ref(a, b, ua, ub, aa, ab)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,d", [(4, 512), (8, 1024), (3, 512)])
def test_per_sample_clip_matches_ref(b, d):
    g = jax.random.normal(jax.random.PRNGKey(2), (b, d), jnp.float32) * 2.5
    got_sum, got_norms = per_sample_clip(g, 1.0, block_d=256, interpret=True)
    want_sum, want_norms = ref.per_sample_clip_ref(g, 1.0)
    np.testing.assert_allclose(np.asarray(got_norms), np.asarray(want_norms),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got_sum), np.asarray(want_sum),
                               rtol=1e-4, atol=1e-5)


def test_luq_quantize_wrapper_odd_shapes():
    x = jax.random.normal(jax.random.PRNGKey(3), (7, 13, 5), jnp.float32)
    q = luq_quantize(x, jax.random.PRNGKey(4))
    assert q.shape == x.shape
    alpha = float(jnp.max(jnp.abs(x)))
    grid = {0.0} | {alpha * 2.0 ** (-k) for k in range(7)}
    for v in np.unique(np.abs(np.asarray(q))):
        assert any(abs(v - g) <= 1e-5 * alpha for g in grid)


def test_luq_matmul_wrapper_unbiased_direction():
    key = jax.random.PRNGKey(5)
    a = jax.random.normal(key, (64, 96))
    b = jax.random.normal(jax.random.fold_in(key, 1), (96, 32))
    outs = [np.asarray(luq_matmul(a, b, jax.random.PRNGKey(i)))
            for i in range(30)]
    mean = np.mean(outs, 0)
    exact = np.asarray(a @ b)
    # many-draw mean approaches the exact product (unbiased quantizers)
    rel = np.linalg.norm(mean - exact) / np.linalg.norm(exact)
    single = np.linalg.norm(outs[0] - exact) / np.linalg.norm(exact)
    assert rel < single / 2, (rel, single)


def test_clip_and_sum_wrapper_pads():
    g = jax.random.normal(jax.random.PRNGKey(6), (4, 333))
    s, norms = clip_and_sum(g, 1.0)
    ws, wn = ref.per_sample_clip_ref(g, 1.0)
    np.testing.assert_allclose(np.asarray(norms), np.asarray(wn), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(ws), rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("b,d,block_d", [
    (1, 333, 512),    # B=1, D below one block
    (1, 512, 512),    # B=1, D exactly one block
    (4, 513, 512),    # D one past a block boundary
    (2, 1500, 512),   # D spanning several blocks, not a multiple
    (1, 1, 512),      # degenerate single-element gradient
    (3, 700, 256),    # non-default block size
])
def test_clip_and_sum_shape_edge_cases(b, d, block_d):
    """clip_and_sum's padding/unpadding contract: (B, D) in ->
    ((D,), (B,)) out matching the ref for any B >= 1 and D not a multiple
    of block_d."""
    g = jax.random.normal(jax.random.PRNGKey(7), (b, d), jnp.float32) * 3.0
    s, norms = clip_and_sum(g, 1.0, block_d=block_d)
    assert s.shape == (d,) and norms.shape == (b,)
    ws, wn = ref.per_sample_clip_ref(g, 1.0)
    np.testing.assert_allclose(np.asarray(norms), np.asarray(wn), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(ws), rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("tdd", [(16, 32, 64), (15, 384, 48),
                                 (8, 100, 200), (130, 768, 384)])
def test_ghost_norm_fused_matches_quantize_composition(tdd):
    """The fused ghost-norm kernel (quantize + Gram + tap-reduce in one
    VMEM pass) must equal the 3-dispatch composition with the pallas
    quantize kernel — SAME keys, bit-identical draws (``luq_uniform``
    pins the layout), fp32 tolerance on the reduction."""
    t, din, dout = tdd
    key = jax.random.PRNGKey(t * din)
    kx, kg, k1, k2 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (t, din), jnp.float32)
    g = jax.random.normal(k2, (t, dout), jnp.float32) * 0.01
    fused = float(ghost_norm_sq(x, g, kx, kg, interpret=True))
    xq = luq_quantize(x, kx, interpret=True).astype(jnp.float32)
    gq = luq_quantize(g, kg, interpret=True).astype(jnp.float32)
    want = float(jnp.vdot(xq @ xq.T, gq @ gq.T))
    np.testing.assert_allclose(fused, want, rtol=2e-5)
    # and the Gram identity itself: equals the direct wgrad norm
    np.testing.assert_allclose(want, float(jnp.sum((xq.T @ gq) ** 2)),
                               rtol=2e-4)


def test_ghost_norm_over_cap_falls_back_unfused():
    """Above GHOST_NORM_MAX_T the (T, T) Gram scratches would not fit
    VMEM on real hardware; the wrapper must fall back to the unfused
    quantize-then-Gram composition with the same keys (bit-identical)."""
    from repro.kernels.ops import GHOST_NORM_MAX_T
    t = GHOST_NORM_MAX_T + 8
    kx, kg = jax.random.split(jax.random.PRNGKey(11))
    x = jax.random.normal(kx, (t, 64), jnp.float32)
    g = jax.random.normal(kg, (t, 32), jnp.float32)
    got = float(ghost_norm_sq(x, g, kx, kg, interpret=True))
    xq = luq_quantize(x, kx, interpret=True).astype(jnp.float32)
    gq = luq_quantize(g, kg, interpret=True).astype(jnp.float32)
    np.testing.assert_allclose(got, float(jnp.vdot(xq @ xq.T, gq @ gq.T)),
                               rtol=2e-5)


def test_ghost_norm_zero_and_scale_edge_cases():
    """All-zero operands (alpha guard) and positive scale invariance
    (the property the ghost reweighted backward relies on)."""
    kx, kg = jax.random.split(jax.random.PRNGKey(3))
    z = jnp.zeros((8, 128), jnp.float32)
    g = jax.random.normal(kg, (8, 128), jnp.float32)
    assert float(ghost_norm_sq(z, g, kx, kg, interpret=True)) == 0.0
    x = jax.random.normal(kx, (8, 128), jnp.float32)
    base = float(ghost_norm_sq(x, g, kx, kg, interpret=True))
    scaled = float(ghost_norm_sq(x, 0.25 * g, kx, kg, interpret=True))
    np.testing.assert_allclose(scaled, 0.0625 * base, rtol=1e-5)


def test_ghost_norm_backend_dispatch(monkeypatch):
    """(ghost_norm, luq_fp4) resolves natively on pallas; other formats
    fall back to ref explicitly; the ref impl matches the ref quantizer
    composition.  REPRO_QUANT_BACKEND is cleared: this test pins the
    per-call dispatch semantics, not the env override (which by design
    beats the request — the CI pallas leg relies on that)."""
    from repro.quant import backend as qb
    from repro.quant.formats import luq_fp4
    monkeypatch.delenv(qb.ENV_VAR, raising=False)
    impl, actual = qb.get_impl("ghost_norm", "luq_fp4", "pallas")
    assert actual == "pallas"
    impl, actual = qb.get_impl("ghost_norm", "fp8_e4m3", "pallas")
    assert actual == "ref"
    impl, actual = qb.get_impl("ghost_norm", "luq_fp4", "ref")
    assert actual == "ref"
    kx, kg = jax.random.split(jax.random.PRNGKey(5))
    x = jax.random.normal(kx, (12, 48), jnp.float32)
    g = jax.random.normal(kg, (12, 24), jnp.float32)
    got = float(impl(x, g, kx, kg))
    xq = luq_fp4(x, kx).astype(jnp.float32)
    gq = luq_fp4(g, kg).astype(jnp.float32)
    np.testing.assert_allclose(got, float(jnp.vdot(xq @ xq.T, gq @ gq.T)),
                               rtol=1e-5)


def test_kernels_package_exports():
    """The public wrappers and raw kernels are importable from the package
    root (the dispatcher and external callers rely on these names)."""
    import repro.kernels as K
    for name in ("luq_quantize", "luq_matmul", "clip_and_sum",
                 "ghost_norm_sq", "luq_quant_2d", "quant_matmul",
                 "per_sample_clip", "ghost_norm_gram", "ref"):
        assert hasattr(K, name), name
        assert name in K.__all__, name
