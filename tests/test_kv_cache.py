"""KV-cache storage quantization: kv_quant / decode_attn op parity
(ref vs pallas), the bf16-scale determinism contract, the zero-scale
invalidation invariant, and the dispatch registry rows (docs/SERVING.md,
docs/QUANTIZATION.md)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import KV_CACHE_FORMATS
from repro.quant import backend as qb
from repro.quant import kv_cache as kvc

QUANT_FMTS = ("int8", "luq_fp4")


def rows(seed, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape,
                                     jnp.float32)


# --------------------------------------------------------------------------- #
# kv_quant: ref vs pallas parity (bit-exact — shared elementwise math)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("fmt", QUANT_FMTS)
@pytest.mark.parametrize("shape", [
    (2, 3, 16, 32),     # typical (B, KV, S, hd)
    (5, 12),            # head_dim not a multiple of the 128 lane tile
    (3, 7, 10),         # row count not a multiple of the row block either
])
def test_kv_quant_ref_pallas_bitwise(fmt, shape, monkeypatch):
    """Codes AND scales must match bit-for-bit across backends: both
    divide by the bf16-rounded scale, so parity is a padding/layout
    question, not a rounding question.  REPRO_QUANT_BACKEND is cleared so
    the ref side stays ref even on the CI pallas leg."""
    monkeypatch.delenv(qb.ENV_VAR, raising=False)
    x = rows(0, shape)
    ref_impl, be_r = qb.get_kv_quant(fmt, "ref")
    pal_impl, be_p = qb.get_kv_quant(fmt, "pallas")
    assert (be_r, be_p) == ("ref", "pallas")
    cr, sr = ref_impl(x)
    cp, sp = pal_impl(x)
    assert cr.dtype == cp.dtype and sr.dtype == sp.dtype == kvc.SCALE_DTYPE
    np.testing.assert_array_equal(np.asarray(cr), np.asarray(cp))
    np.testing.assert_array_equal(np.asarray(sr, np.float32),
                                  np.asarray(sp, np.float32))


@pytest.mark.parametrize("fmt", QUANT_FMTS)
def test_kv_quant_roundtrip_error_bounded(fmt):
    """Dequantized rows stay within one quantization step of the input
    (int8: scale/2 per element; luq_fp4: coarse log grid, bounded by a
    fraction of the row amax)."""
    x = rows(1, (4, 6, 32), scale=3.0)
    codes, scales = kvc.kv_quant(fmt, x)
    deq = kvc.kv_dequant(fmt, codes, scales)
    err = np.abs(np.asarray(deq) - np.asarray(x))
    amax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    # bf16 scale rounding adds <= 2^-8 relative slack on top of the step
    if fmt == "int8":
        bound = amax * (0.5 / kvc.INT8_QMAX) * 1.02
    else:
        # nearest-level on the {0} U {2^-k} grid: worst case is half the
        # gap between the two largest levels, amax * (1 - 0.5)/... = amax/3
        # at the top octave boundary; use the safe analytic bound amax/3
        bound = amax / 3.0 * 1.02
    assert (err <= bound + 1e-7).all()


@pytest.mark.parametrize("fmt", QUANT_FMTS)
def test_kv_quant_deterministic_and_bf16_scales(fmt):
    """Quantization takes no RNG key: identical inputs produce identical
    codes/scales, and stored scales are exactly representable in bf16 —
    the two halves of the engine-vs-oneshot equivalence contract."""
    x = rows(2, (3, 5, 16))
    c1, s1 = kvc.kv_quant(fmt, x)
    c2, s2 = kvc.kv_quant(fmt, x)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(s1, np.float32),
                                  np.asarray(s2, np.float32))
    s32 = np.asarray(s1, np.float32)
    np.testing.assert_array_equal(
        s32, np.asarray(jnp.asarray(s32).astype(kvc.SCALE_DTYPE),
                        np.float32))


def test_fp4_odd_head_dim_rejected():
    """luq_fp4 packs two codes per byte along head_dim, so an odd head_dim
    must fail loudly at spec time, not corrupt the cache silently."""
    with pytest.raises(ValueError, match="even head_dim"):
        kvc.code_spec("luq_fp4", 7)


def test_zero_scale_rows_dequantize_to_exactly_zero():
    """A zero scale decodes any stored codes to exactly 0 — the invariant
    behind SlotPool release hardening: the engine zeroes a retired slot's
    scale rows so a refilled slot cannot read the predecessor's rows."""
    for fmt in QUANT_FMTS:
        _, code_dim = kvc.code_spec(fmt, 16)
        dt = jnp.int8 if fmt == "int8" else jnp.uint8
        codes = jnp.full((2, 3, code_dim), 0x55, dt)   # arbitrary garbage
        scales = jnp.zeros((2, 3), kvc.SCALE_DTYPE)
        deq = np.asarray(kvc.kv_dequant(fmt, codes, scales))
        assert (deq == 0.0).all()


# --------------------------------------------------------------------------- #
# decode_attn: ref vs pallas parity (fp32 tolerance — the kernel folds the
# scales into the score matrix post-matmul, reassociating the products)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("fmt", QUANT_FMTS)
@pytest.mark.parametrize("geom", [
    # (B, KV, group, head_dim, S): both tile-aligned and ragged shapes —
    # head_dim 12 is not a multiple of any lane tile (fp4 packs it to 6
    # bytes), S=10 is not a sublane multiple
    (2, 2, 4, 32, 16),
    (3, 2, 3, 12, 10),
])
def test_decode_attn_ref_pallas_parity(fmt, geom, monkeypatch):
    monkeypatch.delenv(qb.ENV_VAR, raising=False)
    B, n_kv, g, hd, S = geom
    q = rows(3, (B, n_kv * g, hd))
    k = rows(4, (B, n_kv, S, hd))
    v = rows(5, (B, n_kv, S, hd))
    kc, ks = kvc.kv_quant(fmt, k)
    vc, vs = kvc.kv_quant(fmt, v)
    pos = jnp.asarray([S - 1, 2, 0][:B], jnp.int32)     # mixed per-slot
    ref_impl, _ = qb.get_decode_attn(fmt, "ref")
    pal_impl, be = qb.get_decode_attn(fmt, "pallas")
    assert be == "pallas"
    scale = 1.0 / np.sqrt(hd)
    a = ref_impl(q, kc, vc, ks, vs, pos, n_kv=n_kv, scale=scale)
    b = pal_impl(q, kc, vc, ks, vs, pos, n_kv=n_kv, scale=scale)
    assert a.shape == b.shape == (B, n_kv * g, hd)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-6)


def test_decode_attn_none_matches_historical_decode_attend():
    """The ``none`` ref impl must be bit-identical to the plain-jnp
    attention the serve path always ran (scores -> mask -> softmax -> PV
    in the same order with the same dtypes)."""
    B, n_kv, g, hd, S = 2, 2, 2, 8, 6
    q = rows(6, (B, n_kv * g, hd))
    k = rows(7, (B, n_kv, S, hd))
    v = rows(8, (B, n_kv, S, hd))
    pos = jnp.asarray([S - 1, 3], jnp.int32)
    scale = 1.0 / np.sqrt(hd)
    out = kvc.ref_decode_attn("none", q, k, v, None, None, pos,
                              n_kv=n_kv, scale=scale)
    # the historical decode_attend expression, inlined
    qg = q.reshape(B, n_kv, g, hd)
    scores = jnp.einsum("bkgd,bksd->bkgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    valid = jnp.arange(S)[None, None, None, :] <= pos[:, None, None, None]
    probs = jax.nn.softmax(jnp.where(valid, scores, -1e30), axis=-1)
    legacy = jnp.einsum("bkgs,bksd->bkgd", probs.astype(v.dtype),
                        v).reshape(B, n_kv * g, hd)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(legacy))


def test_decode_attn_masks_stale_rows_beyond_pos(monkeypatch):
    """Rows past a slot's position must contribute exactly zero weight —
    overwriting them with garbage (a reused slot before its decode writes
    land) cannot change the output."""
    monkeypatch.delenv(qb.ENV_VAR, raising=False)
    B, n_kv, g, hd, S = 1, 1, 2, 16, 8
    q = rows(9, (B, n_kv * g, hd))
    k = rows(10, (B, n_kv, S, hd))
    v = rows(11, (B, n_kv, S, hd))
    pos = jnp.asarray([3], jnp.int32)
    for fmt in QUANT_FMTS:
        kc, ks = kvc.kv_quant(fmt, k)
        vc, vs = kvc.kv_quant(fmt, v)
        # poison every row beyond pos with huge garbage
        k_bad = k.at[:, :, 4:].set(1e4)
        v_bad = v.at[:, :, 4:].set(-1e4)
        kcb, ksb = kvc.kv_quant(fmt, k_bad)
        vcb, vsb = kvc.kv_quant(fmt, v_bad)
        for backend in ("ref", "pallas"):
            impl, _ = qb.get_decode_attn(fmt, backend)
            clean = impl(q, kc, vc, ks, vs, pos, n_kv=n_kv, scale=0.25)
            dirty = impl(q, kcb, vcb, ksb, vsb, pos, n_kv=n_kv, scale=0.25)
            np.testing.assert_array_equal(np.asarray(clean),
                                          np.asarray(dirty))


# --------------------------------------------------------------------------- #
# dispatch registry
# --------------------------------------------------------------------------- #
def test_none_falls_back_to_ref_explicitly(monkeypatch):
    """There is no pallas kernel for ``none`` (nothing to dequantize); a
    pallas request must resolve to ref and SAY so."""
    monkeypatch.delenv(qb.ENV_VAR, raising=False)
    for getter in (qb.get_kv_quant, qb.get_decode_attn):
        _, be = getter("none", "pallas")
        assert be == "ref"


def test_capability_table_rows():
    """The registry rows the docs table is synced against."""
    table = qb.capability_table()
    for op in ("kv_quant", "decode_attn"):
        assert table[op]["ref"] == tuple(sorted(KV_CACHE_FORMATS))
        assert table[op]["pallas"] == ("int8", "luq_fp4")
