"""Per-arch smoke tests (assignment requirement): reduced same-family config,
one forward/train step on CPU, output shapes + no NaNs; plus decode parity
for the serving families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# ~10 min on a 2-core CPU (one DP train step per registered arch) — runs in
# the full-suite CI job; the fast tier-1 lane deselects it (-m "not slow").
pytestmark = pytest.mark.slow

from repro.config import DPConfig, OptimConfig, QuantConfig, RunConfig
from repro.configs import ASSIGNED_ARCHS, get_smoke_config, list_archs
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_train_setup
from repro.models.registry import build_model

PAPER_ARCHS = ["resnet18", "resnet50", "densenet121", "bert-snli"]


def _batch_for(model, cfg, b, s, key):
    batch = {}
    for k, sds in model.batch_spec(b, s).items():
        if sds.dtype == jnp.int32 and sds.ndim == 2:
            batch[k] = jax.random.randint(key, sds.shape, 0,
                                          max(cfg.vocab_size, 4))
        elif sds.dtype == jnp.int32:
            batch[k] = jax.random.randint(key, sds.shape, 0,
                                          max(cfg.num_classes, 2))
        else:
            batch[k] = jax.random.normal(key, sds.shape, sds.dtype)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + PAPER_ARCHS)
def test_smoke_dp_train_step(arch):
    cfg = get_smoke_config(arch)
    quant = QuantConfig(fmt="luq_fp4")
    model = build_model(cfg, quant)
    run = RunConfig(model=cfg, quant=quant,
                    dp=DPConfig(enabled=True, microbatch_size=2),
                    optim=OptimConfig(name="sgd", lr=0.1),
                    global_batch=4, seq_len=16)
    mesh = make_host_mesh()
    setup = build_train_setup(model, run, mesh)
    step = jax.jit(setup.step_fn, in_shardings=setup.in_shardings,
                   out_shardings=setup.out_shardings)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = setup.opt_init_fn(params)
    batch = _batch_for(model, cfg, 4, 16, jax.random.PRNGKey(1))
    flags = jnp.ones((cfg.policy_len(),), jnp.float32)
    p2, o2, m = step(params, opt_state, batch, jnp.uint32(3), flags,
                     jnp.float32(0.1))
    assert np.isfinite(float(m["loss"]))
    # params actually moved and stayed finite
    moved = 0.0
    for a, b in zip(jax.tree_util.tree_leaves(p2),
                    jax.tree_util.tree_leaves(params)):
        assert np.isfinite(np.asarray(a)).all()
        moved += float(jnp.abs(a - b).sum())
    assert moved > 0


@pytest.mark.parametrize("arch", ["gemma-7b", "yi-6b", "whisper-medium",
                                  "mamba2-130m", "recurrentgemma-9b",
                                  "internvl2-1b", "kimi-k2-1t-a32b"])
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, QuantConfig(fmt="none"))
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(model, cfg, 2, 16, jax.random.PRNGKey(1))
    logits, cache = model.prefill(params, batch, cache_len=24)
    assert logits.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = model.decode_step(params, cache, tok)
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


def test_dense_decode_matches_forward():
    cfg = get_smoke_config("gemma-7b")
    model = build_model(cfg, QuantConfig(fmt="none"))
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    logits, cache = model.prefill(params, {"tokens": toks}, cache_len=16)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    dec_logits, _ = model.decode_step(params, cache, nxt)
    from repro.models import transformer as T
    h = T.forward_hidden(params, jnp.concatenate([toks, nxt[:, None]], 1),
                         jnp.zeros((cfg.n_layers,)), cfg, model.quant)
    ref = jnp.einsum("bd,vd->bv", h[:, -1].astype(jnp.float32),
                     params["embed"].astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(ref),
                               rtol=3e-3, atol=3e-3)


def test_ssd_matches_naive_recurrence():
    from repro.models.mamba2 import ssd_chunked
    key = jax.random.PRNGKey(3)
    b, S, H, P, N = 2, 16, 3, 5, 7
    x = jax.random.normal(key, (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)))
    B_ = jax.random.normal(jax.random.fold_in(key, 3), (b, S, N))
    C_ = jax.random.normal(jax.random.fold_in(key, 4), (b, S, N))
    y = ssd_chunked(x, dt, A, B_, C_, chunk=4, flag=jnp.float32(0),
                    seed=jnp.uint32(0), quant=QuantConfig(fmt="none"))
    h = np.zeros((b, H, P, N))
    ys = []
    for t in range(S):
        a = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None, :])
        xdt = np.asarray(x[:, t]) * np.asarray(dt[:, t])[..., None]
        h = h * a[:, :, None, None] + np.einsum("bhp,bn->bhpn", xdt,
                                                np.asarray(B_[:, t]))
        ys.append(np.einsum("bhpn,bn->bhp", h, np.asarray(C_[:, t])))
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1),
                               rtol=2e-4, atol=2e-4)


def test_moe_dense_capacity_agree_when_no_drop():
    from repro.config import ModelConfig
    kw = dict(family="moe_lm", n_layers=1, d_model=16, n_heads=2,
              n_kv_heads=1, head_dim=8, n_experts=4, top_k=2, expert_d_ff=32,
              vocab_size=53, compute_dtype="float32", attn_chunk_q=8,
              ce_chunk=8, pad_vocab_to=16, moe_capacity_factor=100.0)
    md = build_model(ModelConfig(name="a", moe_impl="dense", **kw),
                     QuantConfig(fmt="none"))
    mc = build_model(ModelConfig(name="b", moe_impl="capacity", **kw),
                     QuantConfig(fmt="none"))
    p = md.init(jax.random.PRNGKey(5))
    b = {"tokens": jax.random.randint(jax.random.PRNGKey(6), (1, 8), 0, 53)}
    ld = md.loss_fn(p, b, None, jnp.zeros((1,)))
    lc = mc.loss_fn(p, b, None, jnp.zeros((1,)))
    np.testing.assert_allclose(float(ld), float(lc), rtol=2e-4)


def test_vocab_padding_masked_in_loss():
    cfg = get_smoke_config("internvl2-1b")
    assert cfg.padded_vocab > cfg.vocab_size
    model = build_model(cfg, QuantConfig(fmt="none"))
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(model, cfg, 2, 12, jax.random.PRNGKey(1))
    loss = model.loss_fn(params, batch, None,
                         jnp.zeros((cfg.policy_len(),)))
    # ~= ln(real vocab), NOT ln(padded vocab)
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.0
