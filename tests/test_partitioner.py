"""Logical-axis partitioner: fallback semantics on synthetic meshes."""
import numpy as np
import pytest
import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel import partitioner as pt


class FakeMesh:
    """Duck-typed mesh (axis_names + devices.shape) for assignment tests."""
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, object)


M = FakeMesh((2, 16, 16), ("pod", "data", "model"))
SP = FakeMesh((16, 16), ("data", "model"))


def spec(logical, shape, mesh=M, rules=None):
    return pt.assign_spec(logical, shape, mesh, rules or pt.DEFAULT_RULES)


def test_batch_pod_data():
    assert spec(("batch", "seq"), (256, 4096)) == P(("pod", "data"), None)


def test_batch_fallback_data_only():
    # batch=16 not divisible by pod*data=32 -> falls to data
    assert spec(("batch", "seq"), (16, 128)) == P("data", None)


def test_batch_indivisible_unsharded():
    assert spec(("batch", "seq"), (1, 524288)) == P(None, None)


def test_kv_cache_head_parallel_vs_seq_parallel():
    # gemma: kv=16 divisible -> head-parallel cache
    s = spec(("layers", "batch", "kv_heads", "kv_seq", "head_dim"),
             (28, 128, 16, 32768, 256))
    assert s == P(None, ("pod", "data"), "model", None, None)
    # yi: kv=4 not divisible -> sequence-parallel cache (flash-decoding)
    s = spec(("layers", "batch", "kv_heads", "kv_seq", "head_dim"),
             (48, 128, 4, 32768, 128))
    assert s == P(None, ("pod", "data"), None, "model", None)


def test_axis_used_once_per_tensor():
    # after heads takes model, kv_seq cannot also take it
    s = spec(("heads", "kv_seq"), (16, 32768))
    assert s == P("model", None)


def test_missing_axis_skipped():
    s = spec(("batch",), (256,), mesh=SP)
    assert s == P("data")


def test_override_rules():
    rules = pt.merge_rules(pt.DEFAULT_RULES, (
        ("experts", (("pod", "model"), ("model",))),
        ("expert_mlp", (("data",),)),
    ))
    s = pt.assign_spec(("layers", "experts", "embed", "expert_mlp"),
                       (61, 384, 7168, 2048), M, rules)
    assert s == P(None, ("pod", "model"), None, "data")
    # single-pod mesh: (pod, model) unavailable -> falls to model
    s = pt.assign_spec(("experts", "embed", "expert_mlp"),
                       (384, 7168, 2048), SP, rules)
    assert s == P("model", None, "data")


def test_tree_shardings_real_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    axes = {"w": ("embed", "mlp"), "b": ("mlp",), "scalar": None}
    abstract = {"w": jax.ShapeDtypeStruct((4, 8), np.float32),
                "b": jax.ShapeDtypeStruct((8,), np.float32),
                "scalar": jax.ShapeDtypeStruct((), np.float32)}
    sh = pt.tree_shardings(axes, abstract, mesh, pt.DEFAULT_RULES)
    assert sh["w"].spec == P(None, "model")
    assert sh["scalar"].spec == P()


def test_rank_mismatch_raises():
    with pytest.raises(ValueError):
        spec(("batch",), (4, 4))
