"""Preemption-safe DP training: mid-epoch checkpoint + bit-identical resume.

The acceptance bar: kill the trainer mid-epoch at a seeded step, restore
in a fresh trainer, finish the run — params, optimizer state, accountant
epsilon, scheduler EMA state, and the RNG stream positions must all match
the uninterrupted run exactly (fp32 tolerance under the ghost gradient
engine, whose Gram einsums may fuse differently across program shapes).
"""
import dataclasses
import os
import signal

import jax
import numpy as np
import pytest

from repro.data.synthetic import ImageClassDataset
from repro.runtime.faults import FaultEvent, FaultPlan
from repro.runtime.preemption import Preempted, PreemptionHandler
from repro.train_loop import Trainer

from test_epoch_executor import assert_trees_equal, small_run


def make_ds():
    return ImageClassDataset(n=256, num_classes=8, image_size=16, noise=0.4)


def preempt_handler(step):
    return PreemptionHandler(
        faults=FaultPlan([FaultEvent(kind="preempt", at=step)]))


def run_uninterrupted(run, epochs=2):
    tr = Trainer(run, make_ds(), mode="dpquant")
    tr.train(epochs)
    return tr


def run_preempted_then_resumed(run, ckpt_dir, at_step, epochs=2):
    """Train until the injected preemption, then resume in a new trainer."""
    tr1 = Trainer(run, make_ds(), mode="dpquant", checkpoint_dir=ckpt_dir,
                  preemption=preempt_handler(at_step))
    with pytest.raises(Preempted) as exc:
        tr1.train(epochs)
    assert exc.value.step == at_step
    # fresh trainer == fresh process: nothing carries over but the files
    tr2 = Trainer(run, make_ds(), mode="dpquant", checkpoint_dir=ckpt_dir)
    resumed = tr2.restore_latest()
    assert resumed is not None
    assert tr2._mid_epoch is not None          # the save was mid-epoch
    assert tr2.step == at_step
    tr2.train(epochs - tr2._next_epoch)
    return tr2


def assert_same_end_state(a: Trainer, b: Trainer, exact=True):
    assert a.step == b.step
    if exact:
        assert_trees_equal(a.params, b.params)
        assert_trees_equal(a.opt_state, b.opt_state)
    else:
        for x, y in zip(jax.tree_util.tree_leaves(a.params),
                        jax.tree_util.tree_leaves(b.params)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=2e-5, atol=1e-6)
    # privacy accounting is exact either way: the executors charge at
    # step/chunk boundaries and identical SGM events merge
    assert (a.accountant.get_epsilon(1e-5) == b.accountant.get_epsilon(1e-5))
    assert (a.accountant.total_steps("train")
            == b.accountant.total_steps("train"))
    assert len(a.accountant.history) == len(b.accountant.history)
    # per-epoch stats (incl. the interrupted epoch's mean loss)
    assert [h.epoch for h in a.history] == [h.epoch for h in b.history]
    if exact:
        np.testing.assert_array_equal([h.loss for h in a.history],
                                      [h.loss for h in b.history])
    # scheduler EMA / policy / analysis-RNG state
    assert_trees_equal(a.scheduler.state_dict(), b.scheduler.state_dict())
    # both RNG streams sit at the same position
    np.testing.assert_array_equal(a.sampler.sample(), b.sampler.sample())
    np.testing.assert_array_equal(a._probe_rng.randint(0, 1 << 30, 8),
                                  b._probe_rng.randint(0, 1 << 30, 8))


# --------------------------------------------------------------------------- #
# mid-epoch preempt + resume == uninterrupted, both executors
# --------------------------------------------------------------------------- #
@pytest.mark.slow
def test_preempt_resume_bitwise_loop_executor(tmp_path):
    run = small_run("loop", steps_per_epoch=4)
    ref = run_uninterrupted(run)
    res = run_preempted_then_resumed(run, tmp_path, at_step=6)
    assert_same_end_state(ref, res)


@pytest.mark.slow
def test_preempt_resume_bitwise_scan_executor(tmp_path):
    """The scan executor checkpoints at chunk boundaries; resuming re-runs
    only the remaining chunks of the interrupted epoch.  Preempting at
    step 10 lands mid-epoch-2 — an *analysis* epoch (interval 2), so the
    resume must not re-run analysis/selection (that would double-consume
    the probe and scheduler RNG streams and double-charge the budget)."""
    run = small_run("scan", chunk=2, steps_per_epoch=4)
    ref = run_uninterrupted(run, epochs=3)
    res = run_preempted_then_resumed(run, tmp_path, at_step=10, epochs=3)
    assert_same_end_state(ref, res)


@pytest.mark.slow
def test_preempt_resume_ghost_engine(tmp_path):
    """Same invariant under the ghost-norm gradient engine (fp32
    tolerance; epsilon and RNG positions stay exact)."""
    base = small_run("loop", steps_per_epoch=4)
    run = dataclasses.replace(
        base, dp=dataclasses.replace(base.dp, grad_mode="ghost"))
    ref = run_uninterrupted(run)
    res = run_preempted_then_resumed(run, tmp_path, at_step=6)
    assert_same_end_state(ref, res, exact=False)


@pytest.mark.slow
def test_mid_epoch_checkpoint_guards_epoch_mismatch(tmp_path):
    run = small_run("loop", steps_per_epoch=4)
    tr1 = Trainer(run, make_ds(), mode="dpquant", checkpoint_dir=tmp_path,
                  preemption=preempt_handler(6))
    with pytest.raises(Preempted):
        tr1.train(2)
    tr2 = Trainer(run, make_ds(), mode="dpquant", checkpoint_dir=tmp_path)
    tr2.restore_latest()
    # the mid-epoch record is for epoch 1; any other epoch must refuse
    with pytest.raises(RuntimeError):
        tr2.train_epoch(0)
    # and the record survives the refusal, so the correct resume still runs
    stats = tr2.train_epoch(1)
    assert stats.epoch == 1


# --------------------------------------------------------------------------- #
# PreemptionHandler unit behavior
# --------------------------------------------------------------------------- #
def test_handler_fault_events_latch_and_clear():
    h = preempt_handler(3)
    assert not h.should_preempt(2)
    assert h.should_preempt(5)       # <= semantics: skipped steps still fire
    assert h.should_preempt(6)       # latched until cleared
    h.clear()
    assert not h.should_preempt(7)   # event already consumed


def test_handler_request_flag():
    h = PreemptionHandler()
    assert not h.should_preempt(0)
    h.request()
    assert h.requested and h.should_preempt(1)


def test_handler_signal_install_uninstall():
    h = PreemptionHandler()
    prev = signal.getsignal(signal.SIGUSR1)
    h.install(signals=(signal.SIGUSR1,))
    try:
        os.kill(os.getpid(), signal.SIGUSR1)
        assert h.requested
    finally:
        h.uninstall()
    assert signal.getsignal(signal.SIGUSR1) is prev
