"""Quantizer-backend dispatch: registry/fallback/env, ref-vs-pallas
equivalence, fused-vs-ref clipping, and executor bit-equivalence on pallas."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import DPConfig, ModelConfig, OptimConfig, QuantConfig, RunConfig
from repro.quant import backend as qb
from repro.quant.formats import STOCHASTIC_FORMATS
from repro.quant.fake_quant import qeinsum

from hypothesis_compat import given, settings, st


# --------------------------------------------------------------------------- #
# registry / resolution
# --------------------------------------------------------------------------- #
def test_capability_table_shape():
    table = qb.capability_table()
    assert set(table) == set(qb.OPS)
    # ref implements every format for quantize/matmul; pallas is LUQ-only
    for op in ("quantize", "matmul"):
        assert "luq_fp4" in table[op]["ref"]
        assert table[op]["pallas"] == ("luq_fp4",)
    # clip is format-agnostic on both backends
    assert table["clip_sum"]["ref"] == (qb.ANY_FORMAT,)
    assert table["clip_sum"]["pallas"] == (qb.ANY_FORMAT,)


def test_explicit_fallback_to_ref():
    _, be = qb.get_quantizer("luq_fp4", "pallas")
    assert be == "pallas"
    _, be = qb.get_quantizer("int4", "pallas")   # pallas lacks int4
    assert be == "ref"
    _, be = qb.get_matmul("fp8_e4m3", "pallas")
    assert be == "ref"
    _, be = qb.get_clip_sum("fused")             # DPConfig alias
    assert be == "pallas"


def test_resolve_backend_env_override(monkeypatch):
    monkeypatch.delenv(qb.ENV_VAR, raising=False)
    assert qb.resolve_backend(None) == "ref"
    assert qb.resolve_backend("pallas") == "pallas"
    monkeypatch.setenv(qb.ENV_VAR, "pallas")
    assert qb.resolve_backend(None) == "pallas"
    assert qb.resolve_backend("ref") == "pallas"   # env wins over config


def test_unknown_backend_raises(monkeypatch):
    monkeypatch.delenv(qb.ENV_VAR, raising=False)
    with pytest.raises(ValueError):
        qb.resolve_backend("cuda")
    monkeypatch.setenv(qb.ENV_VAR, "bogus")
    with pytest.raises(ValueError):
        qb.resolve_backend(None)


# --------------------------------------------------------------------------- #
# backend equivalence: quantizer properties
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("fmt", STOCHASTIC_FORMATS)
def test_stochastic_quantizer_unbiased(fmt, backend):
    """E[q(x)] ~ x for every stochastic format on both backends."""
    q, _ = qb.get_quantizer(fmt, backend)
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 24), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(1), 96)
    draws = jax.vmap(lambda k: q(x, k))(keys)
    mean = np.asarray(draws, np.float32).mean(axis=0)
    resid = np.linalg.norm(mean - np.asarray(x))
    single = np.linalg.norm(np.asarray(draws[0], np.float32) - np.asarray(x))
    # the many-draw mean must contract toward x (unbiasedness); a biased
    # quantizer leaves a floor the averaging cannot remove
    assert resid < single / 3, (fmt, backend, resid, single)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("shape", [(7, 13, 5), (1, 1), (257,), (3, 130)])
def test_luq_odd_shapes_stay_on_grid(backend, shape):
    q, _ = qb.get_quantizer("luq_fp4", backend)
    x = jax.random.normal(jax.random.PRNGKey(2), shape, jnp.float32)
    out = q(x, jax.random.PRNGKey(3))
    assert out.shape == x.shape
    alpha = float(jnp.max(jnp.abs(x)))
    grid = {0.0} | {alpha * 2.0 ** (-k) for k in range(7)}
    for v in np.unique(np.abs(np.asarray(out, np.float32))):
        assert any(abs(v - g) <= 1e-5 * max(alpha, 1.0) for g in grid), \
            (backend, shape, v)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("fmt", STOCHASTIC_FORMATS)
def test_all_zero_tensor_quantizes_to_zero(fmt, backend):
    q, _ = qb.get_quantizer(fmt, backend)
    x = jnp.zeros((9, 33), jnp.float32)
    out = q(x, jax.random.PRNGKey(4))
    np.testing.assert_array_equal(np.asarray(out), 0.0)


@settings(deadline=None, max_examples=8)
@given(st.integers(min_value=1, max_value=64),
       st.integers(min_value=1, max_value=64))
def test_pallas_matmul_unbiased_property(m, n):
    """Property: the fused pallas matmul's many-draw mean approaches the
    exact product for arbitrary (non-tile-multiple) shapes."""
    k = 32
    a = jax.random.normal(jax.random.PRNGKey(m), (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(n + 1000), (k, n), jnp.float32)
    mm, be = qb.get_matmul("luq_fp4", "pallas")
    assert be == "pallas"
    keys = jax.random.split(jax.random.PRNGKey(7), 24)
    draws = np.asarray(jax.vmap(lambda kk: mm(a, b, kk))(keys))
    exact = np.asarray(a @ b)
    rel = np.linalg.norm(draws.mean(0) - exact) / np.linalg.norm(exact)
    single = np.linalg.norm(draws[0] - exact) / np.linalg.norm(exact)
    assert rel < single / 2 + 1e-6, (m, n, rel, single)


def test_qeinsum_backend_value_close_to_ref_statistically():
    """qeinsum(pallas) and qeinsum(ref) draw different random bits but both
    are unbiased — their per-draw means must converge to the same GEMM."""
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 48))
    w = jax.random.normal(jax.random.PRNGKey(1), (48, 16))
    exact = np.asarray(x @ w)

    def mean_out(backend, n=24):
        outs = [np.asarray(qeinsum("ab,bc->ac", x, w, seed=jnp.uint32(i),
                                   flag=jnp.float32(1), backend=backend))
                for i in range(n)]
        return np.mean(outs, 0)

    rel_ref = np.linalg.norm(mean_out("ref") - exact) / np.linalg.norm(exact)
    rel_pal = np.linalg.norm(mean_out("pallas") - exact) / np.linalg.norm(exact)
    assert rel_ref < 0.15 and rel_pal < 0.15, (rel_ref, rel_pal)


# --------------------------------------------------------------------------- #
# fused clip vs ref clip
# --------------------------------------------------------------------------- #
def _quad_loss(params, ex, rng):
    del rng
    return (0.5 * jnp.sum((params["w"] * ex["x"] - ex["y"]) ** 2)
            + jnp.sum(params["b"] * ex["x"][:2]))


def test_fused_clip_matches_ref_grads_and_metrics():
    from repro.dp.clip import per_example_clipped_grad_sum
    key = jax.random.PRNGKey(0)
    batch = {"x": jax.random.normal(key, (8, 5)) * 2.0,
             "y": jax.random.normal(jax.random.fold_in(key, 1), (8, 5))}
    params = {"w": jnp.arange(1.0, 6.0), "b": jnp.ones((2,)) * 0.3}
    outs = {}
    for cb in ("ref", "fused"):
        outs[cb] = per_example_clipped_grad_sum(
            _quad_loss, params, batch, clip_norm=0.9, microbatch_size=4,
            rng=jax.random.PRNGKey(0), clip_backend=cb)
    g_ref, m_ref = outs["ref"]
    g_fused, m_fused = outs["fused"]
    for leaf_r, leaf_f in zip(jax.tree_util.tree_leaves(g_ref),
                              jax.tree_util.tree_leaves(g_fused)):
        np.testing.assert_allclose(np.asarray(leaf_r), np.asarray(leaf_f),
                                   rtol=1e-5, atol=1e-6)
    for k in ("loss", "grad_norm_mean", "grad_norm_max", "clip_fraction"):
        np.testing.assert_allclose(float(m_ref[k]), float(m_fused[k]),
                                   rtol=1e-5, err_msg=k)


def test_fused_clip_rejects_partial_accum():
    from repro.dp.clip import per_example_clipped_grad_sum
    batch = {"x": jnp.ones((4, 3)), "y": jnp.ones((4, 3))}
    params = {"w": jnp.ones((3,)), "b": jnp.ones((2,))}
    with pytest.raises(ValueError, match="partial"):
        per_example_clipped_grad_sum(
            _quad_loss, params, batch, clip_norm=1.0, microbatch_size=4,
            rng=jax.random.PRNGKey(0), clip_backend="fused",
            partial_accum_shards=2)


def test_clip_backend_validated():
    from repro.dp.clip import per_example_clipped_grad_sum
    with pytest.raises(ValueError, match="clip_backend"):
        per_example_clipped_grad_sum(
            _quad_loss, {"w": jnp.ones(3), "b": jnp.ones(2)},
            {"x": jnp.ones((2, 3)), "y": jnp.ones((2, 3))},
            clip_norm=1.0, microbatch_size=2, rng=jax.random.PRNGKey(0),
            clip_backend="pallas")   # DPConfig spelling is "fused"


# --------------------------------------------------------------------------- #
# full-train-step parity + executor bit-equivalence on pallas
# --------------------------------------------------------------------------- #
def _tiny_run(**kw):
    model = ModelConfig(name="resnet-tiny", family="resnet",
                        resnet_blocks=(1,), num_classes=4, image_size=8,
                        compute_dtype="float32")
    defaults = dict(
        model=model,
        quant=QuantConfig(fmt="luq_fp4"),
        dp=DPConfig(enabled=True, clip_norm=1.0, noise_multiplier=0.8,
                    microbatch_size=4, analysis_interval=100),
        optim=OptimConfig(name="sgd", lr=0.2),
        global_batch=4, steps_per_epoch=2, steps=8, seed=0)
    defaults.update(kw)
    return RunConfig(**defaults)


def _train_params(run, epochs=1):
    from repro.data.synthetic import ImageClassDataset
    from repro.train_loop import Trainer
    ds = ImageClassDataset(n=64, num_classes=4, image_size=8, noise=0.3,
                           seed=0)
    tr = Trainer(run, ds, mode="static")
    for e in range(epochs):
        tr.train_epoch(e)
    return tr.params, tr.history


def test_train_step_parity_fused_vs_ref_clip():
    """Identical seeds + quant draws; only the clip implementation differs —
    final params must agree to fp32 tolerance."""
    run_ref = _tiny_run()
    run_fused = _tiny_run(dp=dataclasses.replace(run_ref.dp,
                                                 clip_backend="fused"))
    p_ref, h_ref = _train_params(run_ref)
    p_fused, h_fused = _train_params(run_fused)
    for lr, lf in zip(jax.tree_util.tree_leaves(p_ref),
                      jax.tree_util.tree_leaves(p_fused)):
        np.testing.assert_allclose(np.asarray(lr), np.asarray(lf),
                                   rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(h_ref[0].loss, h_fused[0].loss, rtol=1e-4)


def test_scan_loop_bit_equivalent_on_pallas_backend():
    """The scan and loop executors must stay bit-identical when every
    quantizer runs through the pallas kernels (interpret mode on CPU)."""
    runs = {ex: _tiny_run(quant=QuantConfig(fmt="luq_fp4",
                                            backend="pallas"),
                          epoch_executor=ex)
            for ex in ("scan", "loop")}
    p_scan, _ = _train_params(runs["scan"])
    p_loop, _ = _train_params(runs["loop"])
    for ls, ll in zip(jax.tree_util.tree_leaves(p_scan),
                      jax.tree_util.tree_leaves(p_loop)):
        np.testing.assert_array_equal(np.asarray(ls), np.asarray(ll))


def test_trainer_rejects_bad_backend_knobs(monkeypatch):
    from repro.data.synthetic import ImageClassDataset
    from repro.train_loop import Trainer
    # the env override intentionally wins over config, so clear it to test
    # the config-validation path
    monkeypatch.delenv(qb.ENV_VAR, raising=False)
    ds = ImageClassDataset(n=16, num_classes=4, image_size=8, seed=0)
    with pytest.raises(ValueError):
        Trainer(_tiny_run(quant=QuantConfig(fmt="luq_fp4", backend="gpu")),
                ds, mode="static")
    bad_dp = dataclasses.replace(_tiny_run().dp, clip_backend="pallas")
    with pytest.raises(ValueError):
        Trainer(_tiny_run(dp=bad_dp), ds, mode="static")
