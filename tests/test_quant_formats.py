"""Quantizer properties — the premises of the paper's Proposition 1:
unbiasedness E[q(x)|x] = x and scale-invariance q(lambda x) = lambda q(x)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.quant.formats import (int4_uniform, luq_fp4, fp8_e4m3, fp8_e5m2,
                                 make_quantizer, LUQ_EXP_LEVELS)


@pytest.mark.parametrize("quant,step_frac", [(luq_fp4, 0.5),
                                             (int4_uniform, 1.0 / 7.0)])
def test_unbiasedness(quant, step_frac):
    """E[q(x) | x] = x, tested per coordinate with a distribution-free
    Hoeffding bound: each draw deviates from x by at most one grid step, so
    |mean - x| <= step * sqrt(ln(2 d / delta) / (2 n)) w.p. 1 - delta.
    (A per-coordinate z-test is fragile for rare-event coords whose
    rounding probability is ~0 or ~1.)"""
    key = jax.random.PRNGKey(0)
    d, n_draws = 512, 2000
    x = jax.random.normal(key, (d,), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(1), n_draws)
    qs = jax.vmap(lambda k: quant(x, k))(keys)
    mean = np.asarray(qs.mean(axis=0))
    xs = np.asarray(x)
    step = float(np.abs(xs).max()) * step_frac      # largest grid gap
    tol = step * np.sqrt(np.log(2 * d / 1e-3) / (2 * n_draws))
    dev = np.abs(mean - xs)
    assert dev.max() < tol, (dev.max(), tol)
    # ... and the mean deviation must be an order tighter than the bound
    assert dev.mean() < tol / 4


@pytest.mark.parametrize("quant", [luq_fp4, int4_uniform])
def test_scale_invariance(quant):
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (256,), jnp.float32)
    for lam in (0.5, 3.0, 1e-3, 1e3):
        q1 = quant(x * lam, jax.random.PRNGKey(7))
        q2 = quant(x, jax.random.PRNGKey(7)) * lam
        np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                                   rtol=1e-5, atol=1e-30)


def test_luq_grid_membership():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (2048,), jnp.float32)
    q = luq_fp4(x, jax.random.PRNGKey(4))
    alpha = float(jnp.max(jnp.abs(x)))
    grid = {0.0} | {alpha * 2.0 ** (-k) for k in range(LUQ_EXP_LEVELS)}
    for v in np.unique(np.abs(np.asarray(q))):
        assert any(abs(v - g) <= 1e-5 * alpha for g in grid), v


def test_luq_variance_scales_with_linf():
    """Prop. 1: Var(q(x)) = Theta(||x||_inf^2)."""
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (256,), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(6), 500)

    def var_of(v):
        qs = jax.vmap(lambda k: luq_fp4(v, k))(keys)
        return float(jnp.var(qs - v[None]).mean())

    v1 = var_of(x)
    v100 = var_of(x * 100.0)
    ratio = v100 / max(v1, 1e-20)
    assert 0.5 * 100 ** 2 < ratio < 2.0 * 100 ** 2, ratio


def test_int4_levels():
    x = jnp.linspace(-1, 1, 1001)
    q = int4_uniform(x, jax.random.PRNGKey(0))
    levels = np.unique(np.asarray(q))
    assert len(levels) <= 15


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "fp8_e5m2", "bf16", "none"])
def test_cast_formats_idempotent(fmt):
    q = make_quantizer(fmt)
    x = jax.random.normal(jax.random.PRNGKey(1), (64,), jnp.float32)
    q1 = q(x, None)
    q2 = q(q1, None)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=300),
       st.floats(min_value=1e-3, max_value=1e3))
def test_luq_bounded_by_max(n, scale):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,), jnp.float32) * scale
    q = luq_fp4(x, jax.random.PRNGKey(n + 1))
    assert float(jnp.max(jnp.abs(q))) <= float(jnp.max(jnp.abs(x))) * (1 + 1e-5)


def test_all_zero_input():
    z = jnp.zeros((32,), jnp.float32)
    for fmt in ("luq_fp4", "int4"):
        q = make_quantizer(fmt)(z, jax.random.PRNGKey(0))
        np.testing.assert_array_equal(np.asarray(q), 0.0)
