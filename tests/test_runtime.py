"""Fault tolerance: heartbeats, stragglers, elastic re-mesh."""
import time

from repro.runtime.elastic import degrade_sequence, plan_remesh
from repro.runtime.heartbeat import FailureDetector, Heartbeat
from repro.runtime.straggler import StragglerDetector


def test_heartbeat_failure_detection(tmp_path):
    now = time.time()
    for hid in range(4):
        Heartbeat(tmp_path, hid).beat(step=10, now=now)
    det = FailureDetector(tmp_path, deadline_s=30.0)
    assert det.dead_hosts(now=now + 1) == []
    # host 2 stops beating
    for hid in (0, 1, 3):
        Heartbeat(tmp_path, hid).beat(step=20, now=now + 60)
    assert det.dead_hosts(now=now + 61) == [2]
    assert det.alive_hosts(now=now + 61) == [0, 1, 3]


def test_straggler_detection():
    det = StragglerDetector(alpha=0.5, k_sigma=2.0, patience=2)
    for step in range(6):
        for hid in range(8):
            det.record(hid, 1.0 if hid != 5 else 3.0)  # host 5 is 3x slower
        det.update_strikes()
    assert det.stragglers() == [5]


def test_straggler_no_false_positive():
    det = StragglerDetector(patience=2)
    for _ in range(5):
        for hid in range(4):
            det.record(hid, 1.0)
        det.update_strikes()
    assert det.stragglers() == []


def test_straggler_single_host_fleet():
    """A one-host fleet has no fleet stats: never flags, never crashes."""
    det = StragglerDetector(patience=1)
    for t in (1.0, 50.0, 1.0, 100.0):
        det.record(0, t)
        det.update_strikes()
    assert det.stragglers() == []
    assert det.hosts[0].strikes == 0


def test_failure_detector_skips_malformed_files(tmp_path):
    """Garbage files matching the heartbeat glob must not be fatal."""
    now = time.time()
    Heartbeat(tmp_path, 3).beat(step=1, now=now)
    # non-numeric host id, missing id, and unreadable JSON
    (tmp_path / "host_banana.hb").write_text('{"step": 1, "t": 0}')
    (tmp_path / "host_.hb").write_text('{"step": 1, "t": 0}')
    (tmp_path / "host_7.hb").write_text("not json {{{")
    det = FailureDetector(tmp_path, deadline_s=30.0)
    snap = det.snapshot(now=now + 1)
    assert sorted(snap) == [3]
    assert det.alive_hosts(now=now + 1) == [3]


def test_elastic_remesh_keeps_tp():
    plan = plan_remesh(n_chips=512, model_parallel=16,
                       per_replica_batch=8, dataset_size=1_000_000)
    assert plan.shape == (32, 16)
    assert plan.global_batch == 256
    # lose 64 chips -> 28 data replicas
    plan2 = plan_remesh(n_chips=448, model_parallel=16,
                        per_replica_batch=8, dataset_size=1_000_000)
    assert plan2.shape == (28, 16)
    assert plan2.sample_rate < plan.sample_rate


def test_elastic_remesh_honors_pods():
    """Regression: pods used to be accepted but silently ignored."""
    plan = plan_remesh(n_chips=512, model_parallel=16,
                       per_replica_batch=8, dataset_size=1_000_000, pods=2)
    assert plan.shape == (2, 16, 16)
    assert plan.axis_names == ("pod", "data", "model")
    assert plan.global_batch == 2 * 16 * 8
    # one pod is the legacy 2D mesh
    flat = plan_remesh(n_chips=512, model_parallel=16,
                       per_replica_batch=8, dataset_size=1_000_000, pods=1)
    assert flat.shape == (32, 16)
    assert flat.axis_names == ("data", "model")
    assert flat.global_batch == plan.global_batch
    # too many pods for even one replica each -> None
    assert plan_remesh(n_chips=31, model_parallel=16, per_replica_batch=8,
                       dataset_size=1_000_000, pods=2) is None


def test_elastic_degrade_sequence():
    plans = degrade_sequence(512, 16, 8, 1_000_000, failures=[64, 128, 300])
    assert len(plans) == 3
    assert plans[-1].shape[0] >= 1
    # catastrophic loss -> None / truncation
    plans = degrade_sequence(32, 16, 8, 1_000_000, failures=[31])
    assert len(plans) == 0


def test_checkpoint_reshard_on_new_mesh(tmp_path):
    """Elastic restart: save under one sharding, restore under another."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import serialization

    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    serialization.save(tmp_path / "c.ckpt", tree)
    mesh = jax.make_mesh((1,), ("model",))
    sh = {"w": NamedSharding(mesh, P("model", None))}
    restored, _ = serialization.restore(tmp_path / "c.ckpt", tree,
                                        shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["w"].sharding == sh["w"]
