"""Continuous-batching engine: oneshot equivalence, slot lifecycle,
quantized decode, the quantized slot-pool KV cache, prefill bucketing,
and the sampling-key schedule (docs/SERVING.md)."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (DPConfig, ModelConfig, OptimConfig, QuantConfig,
                          RunConfig, ServeConfig)
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model
from repro.serve import (ContinuousEngine, SlotPool, build_oneshot_fns,
                         oneshot_generate, sampling_key)

VOCAB = 64


def tiny_cfg():
    return ModelConfig(name="lm-tiny", family="dense_lm", n_layers=2,
                       d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                       d_ff=64, vocab_size=VOCAB, compute_dtype="float32",
                       remat=False)


def make_model(fmt="none", backend="ref"):
    cfg = tiny_cfg()
    model = build_model(cfg, QuantConfig(fmt=fmt, backend=backend))
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def prompt_of(seed, length):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed),
                                         (length,), 0, VOCAB), np.int32)


def oneshot_reference(model, params, prompt, gen, kv_fmt="none"):
    """Tokens from the lockstep reference driver for one greedy request."""
    run = RunConfig(model=model.config, quant=model.quant,
                    dp=DPConfig(enabled=False), optim=OptimConfig())
    prefill, decode = build_oneshot_fns(model, run, make_host_mesh(), 1,
                                        prompt.size + gen, kv_fmt=kv_fmt)
    tokens, _ = oneshot_generate(prefill, decode, params,
                                 {"tokens": jnp.asarray(prompt)[None, :]},
                                 gen)
    return tokens[0].tolist()


# --------------------------------------------------------------------------- #
# engine vs oneshot token equivalence
# --------------------------------------------------------------------------- #
@pytest.mark.slow
@pytest.mark.parametrize("fmt,backend", [("none", "ref"),
                                         ("luq_fp4", "ref"),
                                         ("luq_fp4", "pallas")])
def test_engine_matches_oneshot_single_greedy(fmt, backend):
    """A single greedy request on a fixed seed must be token-identical to
    the oneshot driver — including through the quantized logits head on
    both dispatcher backends (same per-position fold of PRNGKey(17))."""
    model, params = make_model(fmt, backend)
    prompt, gen = prompt_of(1, 7), 5
    ref = oneshot_reference(model, params, prompt, gen)
    engine = ContinuousEngine(model, params,
                              ServeConfig(max_slots=1,
                                          max_seq=prompt.size + gen))
    rid = engine.submit(prompt, max_new_tokens=gen)
    out = engine.run()
    assert out[rid].tokens.tolist() == ref


@pytest.mark.slow
def test_mixed_length_requests_each_match_their_oneshot_reference():
    """Multiple requests with different prompt/generation lengths sharing
    two slots must each reproduce their own single-request reference —
    continuous batching may not leak state across slots."""
    model, params = make_model()
    engine = ContinuousEngine(model, params,
                              ServeConfig(max_slots=2, max_seq=24))
    specs = [(3, 6), (9, 3), (5, 2), (4, 7)]       # (prompt_len, gen)
    rids = [engine.submit(prompt_of(10 + i, pl), max_new_tokens=g)
            for i, (pl, g) in enumerate(specs)]
    out = engine.run()
    assert sorted(out) == sorted(rids)
    for rid, (pl, g) in zip(rids, specs):
        assert out[rid].tokens.size == g
        ref = oneshot_reference(model, params, prompt_of(10 + rids.index(rid), pl), g)
        assert out[rid].tokens.tolist() == ref


# --------------------------------------------------------------------------- #
# slot lifecycle
# --------------------------------------------------------------------------- #
def test_slot_reuse_after_retirement():
    """With one slot and three queued requests the slot must be acquired
    three times and every request must complete."""
    model, params = make_model()
    engine = ContinuousEngine(model, params,
                              ServeConfig(max_slots=1, max_seq=16))
    rids = [engine.submit(prompt_of(20 + i, 4 + i), max_new_tokens=3)
            for i in range(3)]
    out = engine.run()
    assert sorted(out) == sorted(rids)
    assert engine.pool.admissions == [3]
    assert engine.pool.n_free == 1 and engine.pool.n_active == 0
    for rid in rids:
        assert out[rid].tokens.size == 3


def test_cache_full_truncates_generation():
    """A slot retires when its next token would not fit in max_seq."""
    model, params = make_model()
    engine = ContinuousEngine(model, params,
                              ServeConfig(max_slots=1, max_seq=10))
    rid = engine.submit(prompt_of(3, 7), max_new_tokens=50)
    out = engine.run()
    # tokens occupy cache indices prompt_len + n - 1; the last admissible
    # token is the one whose write index is max_seq - 1, plus the final
    # sampled-but-never-cached token
    assert out[rid].tokens.size == 10 - 7 + 1


def test_eos_retires_slot_early():
    """EOS seen in the sampled stream stops the request immediately."""
    model, params = make_model()
    prompt, gen = prompt_of(1, 7), 6
    full = oneshot_reference(model, params, prompt, gen)
    eos = full[2]       # third greedy token acts as the EOS id
    engine = ContinuousEngine(model, params,
                              ServeConfig(max_slots=1,
                                          max_seq=prompt.size + gen))
    rid = engine.submit(prompt, max_new_tokens=gen, eos_id=eos)
    out = engine.run()
    assert out[rid].tokens.tolist() == full[:3]


def test_submit_validation():
    model, params = make_model()
    engine = ContinuousEngine(model, params,
                              ServeConfig(max_slots=1, max_seq=8))
    with pytest.raises(ValueError, match="empty"):
        engine.submit(np.zeros((0,), np.int32))
    with pytest.raises(ValueError, match="max_seq"):
        engine.submit(np.zeros((9,), np.int32))
    with pytest.raises(ValueError, match="max_new_tokens"):
        engine.submit(np.zeros((3,), np.int32), max_new_tokens=0)


def test_engine_requires_slot_decode_support():
    cfg = ModelConfig(name="rn", family="resnet", resnet_blocks=(1,),
                      num_classes=4, image_size=8, compute_dtype="float32")
    model = build_model(cfg, QuantConfig(fmt="none"))
    with pytest.raises(ValueError, match="continuous batching"):
        ContinuousEngine(model, params=None,
                         serve=ServeConfig(max_slots=1, max_seq=8))


def test_serve_config_validation():
    with pytest.raises(ValueError, match="max_slots"):
        ServeConfig(max_slots=0)
    with pytest.raises(ValueError, match="max_seq"):
        ServeConfig(max_seq=1)


def test_injected_clock_gates_admission_and_frozen_clock_raises():
    """An advancing fake clock delays admission until arrival_time; a
    frozen fake clock must raise instead of hanging the scheduler."""
    model, params = make_model()
    engine = ContinuousEngine(model, params,
                              ServeConfig(max_slots=1, max_seq=16))
    engine.submit(prompt_of(1, 4), max_new_tokens=2, arrival_time=0.5)

    ticks = {"n": 0}

    def advancing_clock():
        ticks["n"] += 1
        return ticks["n"] * 0.01            # 10 ms per observation

    out = engine.run(clock=advancing_clock)
    assert len(out) == 1
    (timing,) = [r.timing for r in out.values()]
    assert timing.admitted >= 0.5           # arrival-gated

    engine.reset()
    engine.submit(prompt_of(1, 4), max_new_tokens=2, arrival_time=1e9)
    with pytest.raises(RuntimeError, match="not advancing"):
        engine.run(clock=lambda: 0.0)


def test_metrics_accumulate_across_sequential_runs():
    """Two run() calls without reset(): throughput must divide the summed
    token count by the summed wall, not by the latest run's wall only."""
    model, params = make_model()
    engine = ContinuousEngine(model, params,
                              ServeConfig(max_slots=1, max_seq=16))
    a = engine.submit(prompt_of(1, 4), max_new_tokens=3)
    engine.run()
    wall1 = engine.metrics.run_wall
    assert wall1 > 0
    b = engine.submit(prompt_of(2, 4), max_new_tokens=3)
    engine.run()
    s = engine.metrics.summary()
    assert engine.metrics.run_wall > wall1
    assert s["n_requests"] == 2 and s["total_new_tokens"] == 6
    assert s["run_wall_s"] == engine.metrics.run_wall
    assert a in engine.results and b in engine.results


def test_reset_restarts_request_ids_for_deterministic_reruns():
    """reset() must reproduce a fresh engine: request ids restart at 0,
    so temperature-sampling keys (which fold the id) are identical."""
    model, params = make_model()
    serve = ServeConfig(max_slots=1, max_seq=16, temperature=0.9, seed=5)
    engine = ContinuousEngine(model, params, serve)

    def one_run():
        rid = engine.submit(prompt_of(2, 5), max_new_tokens=4)
        assert rid == 0
        out = engine.run()
        engine.reset()
        return out[rid].tokens.tolist()

    assert one_run() == one_run()


def test_slot_pool_free_list():
    pool = SlotPool(2)
    a = pool.acquire(0, 4, 8)
    b = pool.acquire(1, 4, 8)
    assert {a, b} == {0, 1} and pool.acquire(2, 4, 8) is None
    pool.release(a)
    assert pool.n_free == 1 and pool.acquire(3, 2, 2) == a


# --------------------------------------------------------------------------- #
# quantized serving smoke
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_quantized_continuous_serving_smoke(backend):
    """luq_fp4 serving under continuous batching completes on both
    dispatcher backends and stays in the (padded) vocab range."""
    model, params = make_model("luq_fp4", backend)
    engine = ContinuousEngine(model, params,
                              ServeConfig(max_slots=2, max_seq=20))
    rids = [engine.submit(prompt_of(30 + i, 4 + 2 * i), max_new_tokens=3)
            for i in range(3)]
    out = engine.run()
    vpad = model.config.padded_vocab
    for rid in rids:
        toks = out[rid].tokens
        assert toks.size == 3
        assert ((toks >= 0) & (toks < vpad)).all()


# --------------------------------------------------------------------------- #
# quantized slot-pool KV cache (ServeConfig.kv_fmt)
# --------------------------------------------------------------------------- #
@pytest.mark.slow
@pytest.mark.parametrize("kv_fmt", ["int8", "luq_fp4"])
def test_engine_matches_oneshot_per_kv_fmt(kv_fmt):
    """With a quantized slot-pool cache the engine must stay token-identical
    to the oneshot driver at the same kv_fmt: quantization is deterministic
    (round-to-nearest against a bf16 scale, no RNG), so both drivers write
    and read bit-identical rows regardless of batching order."""
    model, params = make_model()
    engine = ContinuousEngine(model, params,
                              ServeConfig(max_slots=2, max_seq=24,
                                          kv_fmt=kv_fmt))
    specs = [(3, 6), (9, 3), (5, 2), (4, 7)]       # (prompt_len, gen)
    rids = [engine.submit(prompt_of(50 + i, pl), max_new_tokens=g)
            for i, (pl, g) in enumerate(specs)]
    out = engine.run()
    for i, (rid, (pl, g)) in enumerate(zip(rids, specs)):
        ref = oneshot_reference(model, params, prompt_of(50 + i, pl), g,
                                kv_fmt=kv_fmt)
        assert out[rid].tokens.tolist() == ref, (kv_fmt, i)


def test_release_zeroes_scale_rows_and_slot_reuse_is_clean():
    """Retiring a slot must zero its scale rows (zero scale dequantizes any
    stored codes to exactly 0), and a request decoded in a *reused* slot
    must produce the same tokens as the same request in a fresh engine —
    the regression for stale-scale leakage across slot generations."""
    model, params = make_model()
    serve = ServeConfig(max_slots=1, max_seq=16, kv_fmt="int8")
    engine = ContinuousEngine(model, params, serve)
    a = engine.submit(prompt_of(60, 5), max_new_tokens=4)
    b = engine.submit(prompt_of(61, 7), max_new_tokens=3)   # reuses slot 0
    out = engine.run()
    reused = out[b].tokens.tolist()
    # every request retired -> every slot's scale rows are zeroed again
    for name in ("k_scale", "v_scale"):
        assert name in engine.cache
        assert (np.asarray(engine.cache[name]) == 0.0).all()
    assert out[a].tokens.size == 4
    # same request, fresh slot generation: must match the reused-slot run
    engine.reset()
    b2 = engine.submit(prompt_of(61, 7), max_new_tokens=3)
    assert engine.run()[b2].tokens.tolist() == reused


def test_unquantized_cache_has_no_scale_arrays():
    """kv_fmt="none" keeps the original cache pytree (k, v, pos only) so
    the unquantized path pays zero memory or dispatch overhead."""
    model, params = make_model()
    engine = ContinuousEngine(model, params,
                              ServeConfig(max_slots=1, max_seq=8))
    assert sorted(engine.cache) == ["k", "pos", "v"]
    assert engine._release_scales is None


def test_engine_rejects_unsupported_kv_fmt():
    """ServeConfig validates against the global format list; the engine
    additionally validates against the *model family's* advertised
    kv_formats so unsupported combinations fail at construction."""
    with pytest.raises(ValueError, match="kv_fmt"):
        ServeConfig(kv_fmt="int4")                 # not a known format
    model, params = make_model()
    limited = dataclasses.replace(model, kv_formats=("none",))
    with pytest.raises(ValueError, match="does not support"):
        ContinuousEngine(limited, params,
                         ServeConfig(max_slots=1, max_seq=8, kv_fmt="int8"))


# --------------------------------------------------------------------------- #
# prefill bucketing (pow2 jit-cache bound)
# --------------------------------------------------------------------------- #
def test_prefill_bucketing_bounds_jit_cache():
    """Admission pads prompts to the next power of two, so a trace with
    many distinct prompt lengths compiles at most log2(max_seq) prefill
    programs instead of one per length."""
    model, params = make_model()
    max_seq = 32
    engine = ContinuousEngine(model, params,
                              ServeConfig(max_slots=2, max_seq=max_seq))
    lengths = [1, 2, 3, 5, 6, 9, 13, 17, 26]       # 9 distinct lengths
    rids = [engine.submit(prompt_of(70 + i, pl), max_new_tokens=2)
            for i, pl in enumerate(lengths)]
    out = engine.run()
    assert sorted(out) == sorted(rids)
    bound = math.ceil(math.log2(max_seq))
    assert engine.prefill_programs <= bound        # 5 buckets for these
    # bucketed (padded) prefill must not change the tokens
    for rid, pl in zip(rids[:2], lengths[:2]):
        ref = oneshot_reference(model, params, prompt_of(70 + rids.index(rid), pl), 2)
        assert out[rid].tokens.tolist() == ref


# --------------------------------------------------------------------------- #
# sampling key schedule (satellite: per-slot, per-position keys)
# --------------------------------------------------------------------------- #
def test_sampling_keys_unique_per_request_and_position():
    """No two (request, position) pairs may share a sampling key — in
    particular two slots decoding the same position draw independent
    bits (the legacy oneshot driver shared one key across the batch)."""
    def key_bits(k):
        try:                       # typed PRNG keys (newer jax defaults)
            return tuple(np.asarray(jax.random.key_data(k)).ravel().tolist())
        except TypeError:          # legacy raw uint32 key arrays
            return tuple(np.asarray(k).ravel().tolist())

    base = jax.random.PRNGKey(0)
    seen = {}
    for rid in range(6):
        for pos in range(20):
            k = key_bits(sampling_key(base, rid, pos))
            assert k not in seen, (rid, pos, seen[k])
            seen[k] = (rid, pos)
    # two slots, same position: distinct keys AND distinct drawn bits
    logits = jnp.zeros((VOCAB,))
    k0 = sampling_key(base, 0, 9)
    k1 = sampling_key(base, 1, 9)
    draws0 = [int(jax.random.categorical(jax.random.fold_in(k0, i), logits))
              for i in range(8)]
    draws1 = [int(jax.random.categorical(jax.random.fold_in(k1, i), logits))
              for i in range(8)]
    assert draws0 != draws1


def test_temperature_sampling_deterministic_across_runs():
    """Same seed + same request ids -> identical sampled tokens, because
    keys depend only on (seed, request_id, position), never on wall time."""
    model, params = make_model()
    serve = ServeConfig(max_slots=2, max_seq=20, temperature=0.9, seed=7)

    def one_run():
        engine = ContinuousEngine(model, params, serve)
        rids = [engine.submit(prompt_of(40 + i, 5), max_new_tokens=4)
                for i in range(3)]
        out = engine.run()
        return [out[r].tokens.tolist() for r in rids]

    assert one_run() == one_run()
