"""Chaos tests: the continuous engine under seeded fault injection.

The acceptance bar (docs/SERVING.md "Failure model & recovery"): under a
seeded ``FaultPlan`` injecting several distinct fault kinds — prefill and
decode dispatch failures, slot-cache poison, a frozen clock, a replica
death — every non-shed request's tokens are bit-identical to a fault-free
run, on both the fp32 and the int8 slot-pool KV cache.  Determinism rests
on the ``(request_id, position)`` sampling-key schedule plus RNG-free KV
quantization, so replayed requests re-derive exactly the tokens they
would have produced.
"""
import pytest

from repro.config import ServeConfig
from repro.runtime.faults import FaultEvent, FaultPlan
from repro.runtime.supervisor import (DegradeToOneshot, ServeSupervisor,
                                      run_supervised)
from repro.serve import ContinuousEngine

from test_serve_engine import make_model, prompt_of

SPECS = [(5, 8), (3, 6), (7, 8), (4, 7)]       # (prompt_len, gen)


def submit_all(engine, specs=SPECS):
    return [engine.submit(prompt_of(40 + i, pl), max_new_tokens=g)
            for i, (pl, g) in enumerate(specs)]


def fault_free_tokens(model, params, serve):
    engine = ContinuousEngine(model, params, serve)
    submit_all(engine)
    out = engine.run()
    return {rid: r.tokens.tolist() for rid, r in out.items()}


def ticking_clock(dt=0.05):
    """Deterministic injected clock: advances ``dt`` per read."""
    t = {"v": 0.0}

    def clock():
        t["v"] += dt
        return t["v"]

    return clock


# --------------------------------------------------------------------------- #
# chaos equivalence: >= 3 fault kinds, tokens bit-identical to fault-free
# --------------------------------------------------------------------------- #
@pytest.mark.slow
@pytest.mark.parametrize("kv_fmt", ["none", "int8"])
def test_chaos_run_is_token_identical_to_fault_free(kv_fmt):
    """Five distinct injected faults (prefill fail, decode fail, slot
    poison, frozen clock, replica death); every request must recover to
    status "ok" with exactly the fault-free token stream, under sampled
    (temperature > 0) decoding on both KV-cache formats."""
    model, params = make_model()
    serve = ServeConfig(max_slots=2, max_seq=16, temperature=1.0, seed=3,
                        kv_fmt=kv_fmt, max_retries=5)
    ref = fault_free_tokens(model, params, serve)

    plan = FaultPlan([
        FaultEvent(kind="prefill_fail", at=1),
        FaultEvent(kind="decode_fail", at=2),
        FaultEvent(kind="replica_death", at=3, target=1),
        FaultEvent(kind="clock_freeze", at=4, duration=6),
        FaultEvent(kind="slot_corrupt", at=5, target=1),
    ], seed=11)
    engine = ContinuousEngine(model, params, serve, faults=plan)
    sup = ServeSupervisor(engine, n_replicas=3, faults=plan,
                          slot_fault_threshold=10)
    submit_all(engine)
    out = run_supervised(engine)

    assert plan.pending == []              # every planned fault fired
    assert sorted(out) == sorted(ref)
    for rid, toks in ref.items():
        assert out[rid].status == "ok"
        assert out[rid].tokens.tolist() == toks
    s = engine.metrics.summary()
    assert s["faults_injected"] == 5
    assert s["retried"] >= 1
    assert s["recovered"] >= 1
    # the replica death triggered the re-plan rung of the degraded ladder
    assert sup.dead == {1}
    assert s["degraded_events"] >= 1
    assert engine.slot_cap == 1            # max(1, 2 slots * 2/3 live)
    assert sup.plans[-1] is not None and sup.plans[-1].shape == (2, 1)


@pytest.mark.slow
def test_oneshot_fallback_drains_token_identically():
    """Repeated slot-pool faults cross the supervisor threshold; the
    oneshot drain must finish the victims' streams bit-identically (it
    replays the engine's own per-(request, position) key schedule)."""
    model, params = make_model()
    serve = ServeConfig(max_slots=2, max_seq=16, temperature=1.0, seed=7,
                        max_retries=5)
    ref = fault_free_tokens(model, params, serve)

    plan = FaultPlan([FaultEvent(kind="slot_corrupt", at=1, target=0),
                      FaultEvent(kind="slot_corrupt", at=2, target=1)],
                     seed=5)
    engine = ContinuousEngine(model, params, serve, faults=plan)
    sup = ServeSupervisor(engine, faults=plan, slot_fault_threshold=2)
    submit_all(engine)
    out = run_supervised(engine)

    assert sup.events[-1]["kind"] == "oneshot_fallback"
    assert engine.metrics.degraded_events >= 1
    assert sorted(out) == sorted(ref)
    for rid, toks in ref.items():
        assert out[rid].status == "ok"
        assert out[rid].tokens.tolist() == toks


def test_degrade_to_oneshot_propagates_from_run():
    """Without run_supervised the degraded-mode abort reaches the caller."""
    model, params = make_model()
    plan = FaultPlan([FaultEvent(kind="slot_corrupt", at=0, target=0)])
    engine = ContinuousEngine(
        model, params, ServeConfig(max_slots=1, max_seq=12), faults=plan)
    ServeSupervisor(engine, faults=plan, slot_fault_threshold=1)
    engine.submit(prompt_of(1, 4), max_new_tokens=4)
    with pytest.raises(DegradeToOneshot):
        engine.run()


# --------------------------------------------------------------------------- #
# individual fault kinds
# --------------------------------------------------------------------------- #
def test_prefill_failure_replays_from_scratch():
    """A prefill dispatch failure re-queues the request before it touches
    a slot; the retry must produce the unfaulted token stream."""
    model, params = make_model()
    serve = ServeConfig(max_slots=1, max_seq=12, temperature=1.0, seed=2)
    ref = fault_free_tokens(model, params, serve)[0]

    plan = FaultPlan([FaultEvent(kind="prefill_fail", at=0)])
    engine = ContinuousEngine(model, params, serve, faults=plan)
    rid = engine.submit(prompt_of(40, SPECS[0][0]),
                        max_new_tokens=SPECS[0][1])
    out = engine.run()
    assert out[rid].status == "ok"
    assert out[rid].tokens.tolist() == ref
    assert engine.metrics.retried == 1
    assert engine.metrics.recovered == 1


def test_retries_exhausted_fails_request():
    """More injected failures than the retry budget -> status "failed"."""
    model, params = make_model()
    serve = ServeConfig(max_slots=1, max_seq=12, max_retries=1)
    plan = FaultPlan([FaultEvent(kind="prefill_fail", at=0),
                      FaultEvent(kind="prefill_fail", at=1)])
    engine = ContinuousEngine(model, params, serve, faults=plan)
    rid = engine.submit(prompt_of(1, 4), max_new_tokens=4)
    out = engine.run()
    assert out[rid].status == "failed"
    assert out[rid].tokens.size == 0
    s = engine.metrics.summary()
    assert s["n_failed"] == 1 and s["n_requests"] == 0


def test_clock_freeze_thaws_and_completes():
    """A frozen clock must hold reads still for the window, then thaw;
    generated tokens are clock-independent."""
    model, params = make_model()
    serve = ServeConfig(max_slots=1, max_seq=12)
    ref = fault_free_tokens(model, params, serve)[0]
    plan = FaultPlan([FaultEvent(kind="clock_freeze", at=0, duration=3)])
    engine = ContinuousEngine(model, params, serve, faults=plan)
    rid = engine.submit(prompt_of(40, SPECS[0][0]),
                        max_new_tokens=SPECS[0][1])
    out = engine.run(clock=ticking_clock())
    assert out[rid].status == "ok"
    assert out[rid].tokens.tolist() == ref
    assert engine.metrics.faults_injected == 1
    assert not plan.has_pending("clock_freeze")


# --------------------------------------------------------------------------- #
# deadlines and load shedding
# --------------------------------------------------------------------------- #
def test_in_flight_deadline_retires_with_partial_tokens():
    model, params = make_model()
    engine = ContinuousEngine(
        model, params,
        ServeConfig(max_slots=1, max_seq=64, deadline_s=1.0))
    rid = engine.submit(prompt_of(1, 4), max_new_tokens=40)
    out = engine.run(clock=ticking_clock(0.05))
    assert out[rid].status == "timed_out"
    assert 0 < out[rid].tokens.size < 40      # partial result survives
    s = engine.metrics.summary()
    assert s["deadline_missed"] == 1 and s["n_timed_out"] == 1
    assert s["total_new_tokens"] == out[rid].tokens.size


def test_queued_deadline_expires_unadmitted():
    """A request whose deadline passes while it waits for a slot is
    rejected without tokens and lands in the metrics' rejected bucket."""
    model, params = make_model()
    engine = ContinuousEngine(model, params,
                              ServeConfig(max_slots=1, max_seq=64))
    r0 = engine.submit(prompt_of(1, 4), max_new_tokens=30)
    r1 = engine.submit(prompt_of(2, 4), max_new_tokens=4, deadline_s=0.5)
    out = engine.run(clock=ticking_clock(0.05))
    assert out[r0].status == "ok" and out[r0].tokens.size == 30
    assert out[r1].status == "timed_out" and out[r1].tokens.size == 0
    s = engine.metrics.summary()
    assert s["n_rejected"] == 1
    assert [r["request_id"] for r in engine.metrics.rejected()] == [r1]


def test_bounded_queue_sheds_overflow_at_submit():
    model, params = make_model()
    engine = ContinuousEngine(
        model, params, ServeConfig(max_slots=1, max_seq=12, max_queue=1))
    rids = [engine.submit(prompt_of(50 + i, 4), max_new_tokens=3)
            for i in range(3)]
    out = engine.run()
    assert out[rids[0]].status == "ok"
    assert [out[r].status for r in rids[1:]] == ["shed", "shed"]
    assert all(out[r].tokens.size == 0 for r in rids[1:])
    s = engine.metrics.summary()
    assert s["shed"] == 2 and s["n_rejected"] == 2
    assert s["n_requests"] == 1


# --------------------------------------------------------------------------- #
# supervisor: replica death via heartbeats, straggler eviction
# --------------------------------------------------------------------------- #
def test_replica_death_detected_through_heartbeat_files(tmp_path):
    """A killed replica stops beating; the FailureDetector declares it
    dead on the shared injected clock and the supervisor re-plans."""
    model, params = make_model()
    serve = ServeConfig(max_slots=2, max_seq=16)
    plan = FaultPlan([FaultEvent(kind="replica_death", at=1, target=2)])
    engine = ContinuousEngine(model, params, serve, faults=plan)
    sup = ServeSupervisor(engine, n_replicas=3, hb_dir=tmp_path,
                          hb_deadline_s=2.0, faults=plan)
    submit_all(engine, SPECS[:2])
    out = engine.run(clock=ticking_clock(0.5))
    assert all(r.status == "ok" for r in out.values())
    assert sup.dead == {2}
    assert sup.live_replicas() == [0, 1]
    assert [e["kind"] for e in sup.events] == ["replan"]
    assert sup.plans[-1].shape == (2, 1)
    assert engine.slot_cap == 1
    assert engine.metrics.degraded_events == 1


def test_straggler_replica_is_evicted():
    """A replica_slow fault inflates one replica's tick EWMA; after
    `patience` strikes the supervisor evicts it like a death."""
    model, params = make_model()
    serve = ServeConfig(max_slots=2, max_seq=16)
    plan = FaultPlan([FaultEvent(kind="replica_slow", at=1, target=5,
                                 factor=4.0)])
    engine = ContinuousEngine(model, params, serve, faults=plan)
    sup = ServeSupervisor(engine, n_replicas=16, faults=plan,
                          straggler_patience=2)
    submit_all(engine, SPECS[:2])
    out = engine.run()
    assert all(r.status == "ok" for r in out.values())
    assert 5 in sup.dead
    assert engine.metrics.degraded_events >= 1
    assert sup.events[0]["kind"] == "replan"
    assert 5 in sup.events[0]["lost"]
