"""End-to-end behaviour tests for the DPQuant system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (DPConfig, ModelConfig, OptimConfig, QuantConfig,
                          RunConfig)
from repro.data.synthetic import ImageClassDataset, TokenDataset
from repro.train_loop import Trainer


def small_cnn_run(mode="dpquant", fmt="luq_fp4", dp_enabled=True,
                  quant_fraction=0.6, seed=0, steps_per_epoch=4,
                  optimizer="sgd"):
    model = ModelConfig(name="cnn", family="resnet", resnet_blocks=(1, 1),
                        num_classes=8, image_size=16,
                        compute_dtype="float32")
    return RunConfig(
        model=model, quant=QuantConfig(fmt=fmt),
        dp=DPConfig(enabled=dp_enabled, clip_norm=1.0, noise_multiplier=1.0,
                    microbatch_size=16, quant_fraction=quant_fraction,
                    analysis_interval=2, analysis_reps=1, beta=10.0),
        optim=OptimConfig(name=optimizer, lr=0.5 if optimizer == "sgd" else 1e-2),
        global_batch=32, steps_per_epoch=steps_per_epoch,
        steps=100, seed=seed)


@pytest.fixture(scope="module")
def datasets():
    train = ImageClassDataset(n=512, num_classes=8, image_size=16, noise=0.4)
    evald = ImageClassDataset(n=128, num_classes=8, image_size=16,
                              noise=0.4, seed=9)
    return train, evald


def test_dpquant_full_loop(datasets):
    train, evald = datasets
    tr = Trainer(small_cnn_run(), train, eval_dataset=evald, mode="dpquant")
    hist = tr.train(4)
    assert hist[-1].eps > 0
    labels = {e.label for e in tr.accountant.history}
    assert labels == {"train", "analysis"}
    assert 0 < tr.accountant.analysis_fraction(1e-5) < 1
    assert hist[-1].quantized_layers == round(0.6 * 3)


def test_loss_decreases_without_noise(datasets):
    """Sanity: DP machinery off, quantization off -> the substrate learns."""
    train, evald = datasets
    run = small_cnn_run(fmt="none", dp_enabled=False, mode="static")
    tr = Trainer(run, train, eval_dataset=evald, mode="static")
    hist = tr.train(5)
    assert hist[-1].loss < hist[0].loss


def test_dp_adam_variant(datasets):
    """Paper A.5: the mechanism composes with DP-Adam unchanged."""
    train, _ = datasets
    tr = Trainer(small_cnn_run(optimizer="adam"), train, mode="dpquant")
    hist = tr.train(2)
    assert np.isfinite(hist[-1].loss)
    assert hist[-1].eps > 0


def test_checkpoint_restart_continuity(tmp_path, datasets):
    """Fault-tolerance: kill after epoch 2, restart, and the accountant
    remembers the spent budget (never under-reports epsilon)."""
    train, _ = datasets
    run = small_cnn_run(seed=3)
    tr1 = Trainer(run, train, mode="dpquant", checkpoint_dir=str(tmp_path))
    tr1.train(2)
    if tr1.ckpt:
        tr1.ckpt.wait()
    eps_before = tr1.accountant.get_epsilon(1e-5)[0]

    tr2 = Trainer(run, train, mode="dpquant", checkpoint_dir=str(tmp_path))
    resumed_epoch = tr2.restore_latest()
    assert resumed_epoch == 1
    eps_after = tr2.accountant.get_epsilon(1e-5)[0]
    assert abs(eps_after - eps_before) < 1e-9
    assert tr2.step == tr1.step
    np.testing.assert_array_equal(tr2.scheduler.scores, tr1.scheduler.scores)
    tr2.train(1)
    assert tr2.accountant.get_epsilon(1e-5)[0] > eps_after


def test_eps_budget_truncation(datasets):
    train, _ = datasets
    tr = Trainer(small_cnn_run(), train, mode="static")
    hist = tr.train(50, eps_budget=3.0)
    assert len(hist) < 50
    assert hist[-1].eps >= 3.0


def test_lm_family_trainer():
    model = ModelConfig(name="lm", family="dense_lm", n_layers=2, d_model=32,
                        n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64,
                        vocab_size=128, compute_dtype="float32",
                        attn_chunk_q=16, ce_chunk=16, pad_vocab_to=16)
    run = RunConfig(model=model, quant=QuantConfig(fmt="luq_fp4"),
                    dp=DPConfig(enabled=True, microbatch_size=4,
                                quant_fraction=0.5, analysis_interval=1,
                                analysis_reps=1),
                    optim=OptimConfig(name="adam", lr=1e-3),
                    global_batch=8, seq_len=32, steps_per_epoch=2,
                    steps=10, seed=0)
    ds = TokenDataset(n=128, vocab=128, seq_len=32)
    tr = Trainer(run, ds, mode="dpquant")
    hist = tr.train(2)
    assert np.isfinite(hist[-1].loss)
    assert tr.scheduler.n_analyses == 2
